"""Accountant / UsageLedger persistable state (ISSUE 6 satellite 2):
plain-dict snapshots that survive a JSON round-trip and restore a
bitwise-equivalent book in a fresh process."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.fairshare import Accountant, UsageLedger
from repro.core.jobqueue import Job, JobQueue


def exercised_ledger():
    led = UsageLedger(half_life_s=3600.0)
    led.add_rate("alice", 4.0, 0.0)
    led.add_rate("bob", 1.0, 100.0)
    led.charge("carol", 250.0, 500.0)
    led.add_rate("alice", -2.0, 1800.0)
    return led


def test_ledger_state_json_round_trip():
    led = exercised_ledger()
    state = json.loads(json.dumps(led.state_dict()))
    led2 = UsageLedger(half_life_s=1.0)     # wrong config on purpose
    led2.load_state(state)
    assert led2.half_life_s == led.half_life_s
    for t in (1800.0, 7200.0, 1e6):
        for key in led.keys():
            assert led2.usage(key, t) == led.usage(key, t), (key, t)
            assert led2.rate(key) == led.rate(key)
    assert led2.keys() == led.keys()


def test_ledger_load_state_validates_half_life():
    with pytest.raises(ValueError):
        UsageLedger().load_state({"half_life_s": 0.0})


def exercised_accountant():
    acct = Accountant(half_life_s=7200.0, base_priority=0.25,
                      default_factor=2.0)
    acct.set_quota("osg", 3.0)
    acct.set_quota("cms", 1.0)
    acct.set_priority_factor("heavy", 10.0)
    q = JobQueue(name="osg")
    acct.attach_queue("osg", q)
    jid = q.submit(Job(ad={"request_cpus": 4, "user": "alice"},
                       runtime_s=600), 0.0)
    q.claim(jid, "w0", 10.0)
    q.submit(Job(ad={"request_cpus": 1, "user": "heavy"},
                 runtime_s=600), 0.0)
    acct.users.charge("heavy", 5000.0, 50.0)
    acct.groups.charge("cms", 800.0, 50.0)
    return acct


def test_accountant_state_json_round_trip():
    acct = exercised_accountant()
    state = json.loads(json.dumps(acct.state_dict()))
    fresh = Accountant()
    fresh.restore(state)
    for t in (100.0, 5000.0, 1e5):
        for u in acct.users.keys():
            assert (fresh.effective_priority(u, t)
                    == acct.effective_priority(u, t)), (u, t)
        for s in acct.groups.keys():
            assert fresh.group_owed(s, t) == acct.group_owed(s, t), (s, t)
    assert fresh.base_priority == acct.base_priority
    assert fresh.default_factor == acct.default_factor
    assert fresh.quotas == acct.quotas
    assert fresh.factors == acct.factors


def test_restore_accepts_full_snapshot():
    """`snapshot(now)` carries the persistable state under its "state"
    key, so a metrics record doubles as a restore point."""
    acct = exercised_accountant()
    snap = json.loads(json.dumps(acct.snapshot(123.0)))
    fresh = Accountant()
    fresh.restore(snap)
    assert fresh.snapshot(456.0) == acct.snapshot(456.0)


def test_restore_drops_virtual_charges():
    """Within-cycle virtual charges are cycle-local and must not leak
    through persistence."""
    acct = exercised_accountant()
    acct.charge_virtual("osg", "alice", 64.0)
    before = acct.effective_priority("alice", 100.0)
    fresh = Accountant()
    fresh.restore(acct.state_dict())
    assert fresh.effective_priority("alice", 100.0) < before


def test_snapshot_gauges_unchanged_by_state_key():
    """The pre-existing gauge fields keep their schema; "state" rides
    alongside."""
    acct = exercised_accountant()
    snap = acct.snapshot(100.0)
    assert set(snap) == {"users", "schedds", "state"}
    assert "effective_priority" in snap["users"]["alice"]
    assert "quota" in snap["schedds"]["osg"]


# ---------------------------------------------------------------------------
# Ledger persistence under ACTIVE flocking: snapshot taken mid-cycle with
# outstanding claims, restored into a fresh federation — usage, priorities
# and the eventual fair-share convergence must be unchanged.
# ---------------------------------------------------------------------------

def _flocking_sim(seed=3):
    from repro.core import (NodeTemplate, ProvisionerConfig, Simulation,
                            gpu_job, onprem_nodes)
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=30)
    sim = Simulation(
        cfg, nodes=onprem_nodes(2, gpus=4, cpus=16),
        node_template=NodeTemplate(
            capacity={"cpu": 16, "gpu": 4, "memory": 64, "disk": 256}),
        max_nodes=8, schedds=2, fairshare=True,
        tick_s=5.0, negotiate_interval_s=15.0, seed=seed)
    for i in range(30):
        sim.submit_jobs(
            10.0 * i,
            [gpu_job(400.0, gpus=1, extra_ad={"user": f"user{i % 3:02d}"})],
            schedd=i % 2)
    return sim


def test_accountant_survives_midcycle_flocking_snapshot():
    sim = _flocking_sim()
    sim.run(350.0)          # past arrivals; claims still outstanding
    assert sim.pool_queue.n_running() > 0, "want outstanding claims"
    state = json.loads(json.dumps(sim.state_dict()))

    sim2 = _flocking_sim()
    sim2.restore(state)
    # the snapshot is a fixed point through a second round trip (checked
    # first: Accountant.snapshot() settles the decay ledger in place)
    state2 = json.loads(json.dumps(sim2.state_dict()))
    assert (json.dumps(state2, sort_keys=True)
            == json.dumps(state, sort_keys=True))
    # and the restored accountant reports identical usage/priorities
    assert (sim2.accountant.snapshot(sim2.now)
            == sim.accountant.snapshot(sim.now))

    # convergence unchanged: both runs drain to the same fair-share end
    sim.run_until_drained(20000.0)
    sim2.run_until_drained(20000.0)
    assert (sim2.accountant.snapshot(sim2.now)
            == sim.accountant.snapshot(sim.now))
    assert sim2.pool_queue.n_running() == 0
