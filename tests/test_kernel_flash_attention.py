"""Pallas flash-attention kernel vs the pure-jnp oracle (interpret mode),
swept over shapes/dtypes/mask modes, plus the chunked production path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference

CASES = [
    # B, Sq, Skv, Hq, Hkv, Dh, causal, window, softcap
    (2, 256, 256, 8, 4, 64, True, None, None),
    (1, 200, 200, 4, 4, 64, True, None, None),        # unaligned seq
    (2, 128, 384, 8, 2, 128, True, 64, None),         # window + GQA
    (1, 1, 256, 8, 4, 64, True, None, None),          # decode row
    (2, 64, 128, 4, 4, 32, False, None, None),        # cross-attn
    (1, 96, 96, 6, 2, 64, True, 32, None),            # window < Sq
    (2, 128, 128, 4, 2, 64, True, None, 30.0),        # logit softcap
    (1, 300, 100, 4, 1, 64, True, None, None),        # Skv < Sq, MQA
]


def _mk(rng, B, Sq, Skv, Hq, Hkv, Dh, dtype):
    q = jnp.asarray(rng.standard_normal((B, Sq, Hq, Dh)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Skv, Hkv, Dh)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Skv, Hkv, Dh)), dtype)
    qp = jnp.broadcast_to(
        jnp.arange(Skv - Sq, Skv, dtype=jnp.int32), (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32), (B, Skv))
    kp = kp.at[:, ::7].set(-1)  # empty cache slots
    return q, k, v, qp, kp


@pytest.mark.parametrize(
    "B,Sq,Skv,Hq,Hkv,Dh,causal,window,softcap", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_oracle(rng, B, Sq, Skv, Hq, Hkv, Dh, causal,
                               window, softcap, dtype):
    q, k, v, qp, kp = _mk(rng, B, Sq, Skv, Hq, Hkv, Dh, dtype)
    out = flash_attention_pallas(
        q, k, v, qp, kp, causal=causal, window=window, softcap=softcap,
        interpret=True)
    ref = attention_reference(
        q, k, v, qp, kp, causal=causal, window=window, softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("Sq,Skv", [(512, 512), (1024, 256), (384, 768)])
def test_chunked_path_matches_oracle(rng, Sq, Skv):
    """The production CPU path (ops.flash_attention) chunks over queries;
    must equal the dense oracle exactly in semantics."""
    q, k, v, qp, kp = _mk(rng, 2, Sq, Skv, 4, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, qp, kp, causal=True)
    ref = attention_reference(q, k, v, qp, kp, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_fully_masked_rows_zero(rng):
    """Rows whose whole kv set is invalid must return 0 (no NaN)."""
    q, k, v, qp, kp = _mk(rng, 1, 8, 16, 2, 2, 32, jnp.float32)
    kp = jnp.full_like(kp, -1)
    out = flash_attention_pallas(q, k, v, qp, kp, causal=True,
                                 interpret=True)
    assert not bool(jnp.any(jnp.isnan(out)))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


def test_decode_rolling_window_consistency(rng):
    """Decode with a rolling buffer (kv_pos holds absolute positions) must
    equal attention over the logically-ordered window."""
    B, C, Hq, Hkv, Dh, W = 1, 64, 4, 2, 32, 32
    pos_abs = jnp.arange(100, 100 + C, dtype=jnp.int32)  # slot i: pos
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, C, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, C, Hkv, Dh)), jnp.float32)
    qp = jnp.asarray([[100 + C]], jnp.int32)
    kp = pos_abs[None, :]
    # rotate the buffer: same (pos, k, v) triplets, scrambled slot order
    perm = np.asarray(rng.permutation(C))
    out1 = flash_attention_pallas(q, k, v, qp, kp, causal=True, window=W,
                                  interpret=True)
    out2 = flash_attention_pallas(q, k[:, perm], v[:, perm], qp,
                                  kp[:, perm], causal=True, window=W,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-5)
