"""Streaming replay: differential vs upfront submission, time-warp,
truncation, lazy submit_jobs, and the 100k-arrival memory regression."""
import weakref

import pytest

from repro.core import ProvisionerConfig, Simulation, onprem_nodes
from repro.workload.generators import synthesize
from repro.workload.replay import replay_trace, submit_trace_upfront
from repro.workload.trace import Trace, TraceRecord


def build_sim(nodes: int = 4, **cfg_kw) -> Simulation:
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=180,
                            startup_delay_s=10,
                            max_pods_per_group=600, max_total_pods=600,
                            **cfg_kw)
    return Simulation(cfg, nodes=onprem_nodes(nodes, gpus=8, cpus=64),
                      tick_s=5, negotiate_interval_s=15,
                      metrics_interval_s=60)


def small_trace(n=200, seed=9) -> Trace:
    return synthesize(n, 1800.0, seed=seed, burst_frac=0.2, n_bursts=2)


def completion_signature(sim: Simulation):
    return sorted((j.submitted_at, j.runtime_s, j.completed_at,
                   j.ad.get("accounting_group"))
                  for j in sim.queue.completed_log)


# -- differential: streaming == upfront --------------------------------------

def test_streaming_replay_matches_upfront_submission():
    trace = small_trace()

    sim_a = build_sim()
    rep = replay_trace(sim_a, trace, coalesce_s=0.0)
    sim_a.run_until_drained(max_t=1e6)

    sim_b = build_sim()
    n = submit_trace_upfront(sim_b, trace)
    sim_b.run_until_drained(max_t=1e6)

    assert n == len(trace)
    assert rep.stats.submitted == len(trace)
    assert rep.exhausted
    assert len(sim_a.queue.completed_log) == len(trace)
    assert completion_signature(sim_a) == completion_signature(sim_b)


def test_exact_arrival_times_without_coalescing():
    trace = Trace.from_records(
        [TraceRecord(arrival_s=t, runtime_s=30.0)
         for t in (0.0, 12.5, 13.75, 600.0)])
    sim = build_sim()
    replay_trace(sim, trace, coalesce_s=0.0)
    sim.run_until_drained(max_t=1e6)
    assert sorted(j.submitted_at for j in sim.queue.completed_log) == \
        [0.0, 12.5, 13.75, 600.0]


def test_coalescing_delays_but_never_drops():
    trace = small_trace()
    sim = build_sim()
    rep = replay_trace(sim, trace, coalesce_s=20.0)
    sim.run_until_drained(max_t=1e6)
    assert rep.stats.submitted == len(trace)
    by_arrival = sorted(r.arrival_s for r in trace.records)
    got = sorted(j.submitted_at for j in sim.queue.completed_log)
    for exact, quantized in zip(by_arrival, got):
        assert exact - 1e-9 <= quantized <= exact + 20.0 + 1e-6


# -- time-warp ---------------------------------------------------------------

def test_time_warp_compresses_arrivals():
    trace = Trace.from_records(
        [TraceRecord(arrival_s=t, runtime_s=10.0)
         for t in (0.0, 100.0, 1000.0)])
    sim = build_sim()
    rep = replay_trace(sim, trace, speed=4.0, coalesce_s=0.0)
    sim.run_until_drained(max_t=1e6)
    assert rep.stats.first_arrival_s == pytest.approx(0.0)
    assert rep.stats.last_arrival_s == pytest.approx(250.0)
    assert sorted(j.submitted_at for j in sim.queue.completed_log) == \
        pytest.approx([0.0, 25.0, 250.0])


# -- truncation windows ------------------------------------------------------

def test_truncation_window():
    trace = Trace.from_records(
        [TraceRecord(arrival_s=float(t), runtime_s=10.0)
         for t in range(0, 1000, 100)])
    sim = build_sim()
    rep = replay_trace(sim, trace, start_s=200.0, until_s=700.0,
                       coalesce_s=0.0)
    sim.run_until_drained(max_t=1e6)
    # kept: arrivals 200..600 (5 records), re-zeroed at sim t=0
    assert rep.stats.submitted == 5
    assert rep.stats.truncated == 5       # 0,100 before + 700,800,900 after
    assert sorted(j.submitted_at for j in sim.queue.completed_log) == \
        pytest.approx([0.0, 100.0, 200.0, 300.0, 400.0])


def test_empty_window_rejected():
    sim = build_sim()
    with pytest.raises(ValueError, match="window"):
        replay_trace(sim, small_trace(), start_s=100.0, until_s=100.0)


# -- lazy submit_jobs (satellite) --------------------------------------------

def test_submit_jobs_accepts_lazy_iterables():
    from repro.core.simulation import gpu_job
    sim = build_sim()
    drawn = []

    def gen():
        for i in range(50):
            drawn.append(i)
            yield gpu_job(30.0, gpus=1)

    sim.submit_jobs(500.0, gen())
    assert drawn == []                     # nothing materialized yet
    sim.run(499.0)
    assert drawn == []                     # still pending
    sim.run(501.0)
    assert len(drawn) == 50                # drawn exactly at fire time
    assert sim.queue.n_idle() + sim.queue.n_running() == 50
    sim.run_until_drained(max_t=1e6)
    assert len(sim.queue.completed_log) == 50


# -- the 100k-arrival memory regression (satellite) --------------------------

def test_100k_replay_bounds_live_jobs():
    """A 100k-arrival streaming replay must never hold more than the
    in-flight window of `Job` objects alive: jobs materialize at arrival
    and are released at completion (compact_completed streams stats
    instead of retaining the completed log)."""
    N = 100_000

    def records():
        for i in range(N):
            yield TraceRecord(arrival_s=i * 0.02, runtime_s=20.0, cpus=1,
                              memory_gb=2.0, group="uniform")

    state = {"live": 0, "peak": 0, "created": 0}

    def factory(rec):
        job = rec.to_job()
        state["created"] += 1
        state["live"] += 1
        state["peak"] = max(state["peak"], state["live"])

        def dec():
            state["live"] -= 1

        weakref.finalize(job, dec)
        return job

    cfg = ProvisionerConfig(submit_interval_s=60, idle_timeout_s=300,
                            startup_delay_s=10, max_pods_per_group=2500,
                            max_total_pods=2500)
    sim = Simulation(cfg, nodes=onprem_nodes(24, gpus=8, cpus=64),
                     tick_s=10, negotiate_interval_s=15,
                     metrics_interval_s=300)
    rep = replay_trace(sim, records(), coalesce_s=2.0,
                       compact_completed=True, job_factory=factory)
    sim.run_until_drained(max_t=1e6)

    assert rep.stats.submitted == N
    assert state["created"] == N
    assert rep.stats.completed is not None
    assert rep.stats.completed.n == N
    assert sim.queue.drained()
    assert sim.queue.completed_log == []   # compacted away
    # the whole point: in-flight window, not the whole campaign
    assert state["peak"] <= 20_000, state
    assert state["live"] == 0              # everything released at the end
    # conservation through the streaming aggregator
    assert rep.stats.completed.core_seconds == pytest.approx(N * 20.0)


def test_compact_completed_streams_wait_stats():
    trace = small_trace(100, seed=3)
    sim = build_sim()
    rep = replay_trace(sim, trace, compact_completed=True, coalesce_s=5.0)
    sim.run_until_drained(max_t=1e6)
    s = rep.stats.completed.summary()
    assert s["n"] == 100
    assert s["p95_wait_s"] >= s["p50_wait_s"] >= 0.0
    assert s["core_hours"] == pytest.approx(
        trace.total_core_seconds() / 3600.0)
