"""Multi-schedd flocking + hierarchical fair-share (core/fairshare.py,
Collector.negotiate_cycle, multi-queue Provisioner deficits).

Pins the PR's contracts:

  * the usage ledger integrates decayed usage in closed form
  * two users with 2:1 priority factors over a long uniform backlog end
    within 5% of a 2:1 running-slot split (HTCondor's inverse-factor
    entitlement), and quotas split the pool across schedds likewise
  * a 1-schedd flocking setup is tick-for-tick identical to the
    existing single-queue path (the compat adapter differential)
  * the provisioner computes deficits from POST-negotiation idle
    cohorts: jobs the next cycle will match to existing (even partial)
    capacity are not provisioned for again — the double-count fix
  * trace splitting is an exact, deterministic, order-preserving
    partition, and a concurrent multi-schedd replay conserves demand
"""
import pytest

from repro.core import (
    Accountant, ClassAdExpr, Collector, Job, JobQueue, KubeCluster, Node,
    Provisioner, ProvisionerConfig, ScheddSpec, Simulation, UsageLedger,
    Worker, gpu_job, onprem_nodes,
)
from repro.workload.generators import diurnal_day
from repro.workload.replay import replay_flock
from repro.workload.trace import split_trace


def mk_cfg(**kw):
    return ProvisionerConfig(
        submit_interval_s=kw.pop("submit_interval_s", 30),
        idle_timeout_s=kw.pop("idle_timeout_s", 120),
        startup_delay_s=kw.pop("startup_delay_s", 30),
        **kw,
    )


def user_job(runtime_s, user, *, gpus=1, cpus=1):
    return gpu_job(runtime_s, gpus=gpus, cpus=cpus,
                   extra_ad={"user": user})


# ---------------------------------------------------------------------------
# UsageLedger: decay + rate integration in closed form
# ---------------------------------------------------------------------------

def test_ledger_halves_usage_per_half_life():
    led = UsageLedger(half_life_s=100.0)
    led.charge("u", 80.0, 0.0)
    assert led.usage("u", 100.0) == pytest.approx(40.0)
    assert led.usage("u", 300.0) == pytest.approx(10.0)


def test_ledger_rate_converges_to_effective_cores():
    """A key holding a steady rate r converges to effective_cores == r
    (usage -> r*hl/ln2), whatever the half-life."""
    led = UsageLedger(half_life_s=50.0)
    led.add_rate("u", 3.0, 0.0)
    # settle in many small steps vs one big step: same closed form
    for t in range(1, 2001):
        led.usage("u", float(t))
    assert led.effective_cores("u", 2000.0) == pytest.approx(3.0,
                                                             rel=1e-6)
    led2 = UsageLedger(half_life_s=50.0)
    led2.add_rate("u", 3.0, 0.0)
    assert led2.usage("u", 2000.0) == pytest.approx(
        led.usage("u", 2000.0), rel=1e-9)


def test_ledger_rate_changes_settle_exactly():
    led = UsageLedger(half_life_s=1e12)   # ~no decay: pure integral
    led.add_rate("u", 2.0, 0.0)
    led.add_rate("u", -2.0, 10.0)         # ran 2 cores for 10 s
    # rel tolerance absorbs the 1-0.5^eps cancellation at huge half-life
    assert led.usage("u", 50.0) == pytest.approx(20.0, rel=1e-4)


def test_accountant_effective_priority_orders_by_factor():
    acct = Accountant(half_life_s=100.0)
    acct.set_priority_factor("heavy", 2.0)
    acct.users.charge("heavy", 100.0, 0.0)
    acct.users.charge("light", 100.0, 0.0)
    assert (acct.effective_priority("heavy", 0.0)
            > acct.effective_priority("light", 0.0))


# ---------------------------------------------------------------------------
# Fair-share convergence: 2:1 priority factors -> 2:1 slot split
# ---------------------------------------------------------------------------

def test_two_user_fair_share_converges_to_inverse_factors():
    """Long uniform backlog from two users with priority factors 2:1 on
    a fixed 48-slot pool: the running-slot split must settle within 5%
    of 2:1 (alice, factor 1, gets two thirds) — on the event engine."""
    acct = Accountant(half_life_s=1800.0)
    acct.set_priority_factor("alice", 1.0)
    acct.set_priority_factor("bob", 2.0)
    sim = Simulation(mk_cfg(idle_timeout_s=300), schedds=1,
                     fairshare=acct, nodes=onprem_nodes(6, gpus=8),
                     tick_s=5)
    jobs = [user_job(120, "alice" if i % 2 == 0 else "bob")
            for i in range(4000)]
    sim.submit_jobs(0, jobs)
    sim.run(2000)
    total = 6 * 8
    for t in (3000, 4000, 5000):
        sim.run(t)
        a = sim.queue.running_by_user.get("alice", 0)
        b = sim.queue.running_by_user.get("bob", 0)
        assert a + b == total, "backlog must keep the pool saturated"
        assert abs(a / total - 2.0 / 3.0) <= 0.05, (t, a, b)


def test_schedd_quotas_split_pool_proportionally():
    """Group layer: two schedds with 3:1 quotas, one user each, both
    with deep backlogs — running slots split ~3:1 across schedds."""
    sim = Simulation(
        mk_cfg(idle_timeout_s=300),
        schedds=[ScheddSpec("big", quota=3.0),
                 ScheddSpec("small", quota=1.0)],
        fairshare=True, nodes=onprem_nodes(6, gpus=8), tick_s=5)
    sim.submit_jobs(0, [user_job(120, "u-big") for _ in range(2000)],
                    schedd="big")
    sim.submit_jobs(0, [user_job(120, "u-small") for _ in range(2000)],
                    schedd="small")
    sim.run(4000)
    big = sim.queue_named("big").n_running()
    small = sim.queue_named("small").n_running()
    assert big + small == 48
    assert abs(big / 48 - 0.75) <= 0.05, (big, small)


def test_fairshare_on_tick_engine_is_rejected():
    """The tick baseline negotiates with per-job FIFO scans and cannot
    honour the accountant — configuring both must fail loudly instead
    of silently ignoring quotas/factors."""
    with pytest.raises(ValueError, match="engine='event'"):
        Simulation(mk_cfg(), engine="tick", schedds=2, fairshare=True,
                   nodes=onprem_nodes(1))


def test_straggler_policy_covers_every_schedd():
    """Mitigation must see RUNNING jobs of all queues, not schedd 0's."""
    from repro.core.stragglers import StragglerPolicy

    pol = StragglerPolicy(factor=2.0, min_runtime_s=0.0)
    sim = Simulation(mk_cfg(idle_timeout_s=600), schedds=2,
                     nodes=onprem_nodes(2, gpus=8), tick_s=5,
                     straggler_policy=pol)
    # schedd01's only job lands on a straggling worker (runs at 1% speed)
    sim.submit_jobs(0, [gpu_job(100, gpus=1)], schedd=1)
    sim.inject_slow_workers(60, frac=1.0, rate=0.01)
    sim.run(2000)
    assert pol.rescheduled >= 1, \
        "straggler on a non-first schedd was never rescheduled"


def test_starvation_age_tracks_current_oldest_not_cohort_history():
    """A continuously-fed cohort must not pin the starvation age at its
    first-ever arrival once that job has been served."""
    q = JobQueue()
    a = Job(ad={"request_cpus": 1, "user": "u"}, runtime_s=60)
    q.submit(a, 0.0)
    q.submit(Job(ad={"request_cpus": 1, "user": "u"}, runtime_s=60),
             500.0)
    q.claim(a.jid, "w0", 510.0)      # the t=0 job starts; cohort lives on
    (n, age), = q.idle_by_user(600.0).values()
    assert n == 1
    assert age == pytest.approx(100.0)   # 600 - 500, not 600 - 0


def test_fair_share_yields_pool_when_competitor_drains():
    """No artificial starvation: when the favoured user's queue empties
    the other user takes the whole pool."""
    acct = Accountant(half_life_s=600.0)
    acct.set_priority_factor("bob", 2.0)
    sim = Simulation(mk_cfg(idle_timeout_s=600), schedds=1,
                     fairshare=acct, nodes=onprem_nodes(2, gpus=8),
                     tick_s=5)
    sim.submit_jobs(0, [user_job(100, "alice") for _ in range(40)]
                    + [user_job(100, "bob") for _ in range(200)])
    sim.run_until_drained(max_t=50_000)
    assert sim.drained()
    done = len(sim.queue.completed_log)
    assert done == 240


# ---------------------------------------------------------------------------
# Differential: 1-schedd flocking == single-queue path, tick for tick
# ---------------------------------------------------------------------------

def _snapshot(sim):
    return (
        round(sim.now, 6),
        sim.queue.n_idle(),
        sim.queue.n_running(),
        len(sim.queue.completed_log),
        sim.provisioner.stats.submitted,
        sorted(sim.collector.workers),
    )


@pytest.mark.parametrize("engine", ["event", "tick"])
def test_one_schedd_flocking_identical_to_single_queue(engine):
    """`schedds=1` (no accountant) must reproduce the single-queue
    construction path exactly — same queue depths, completions, pod
    submissions, and worker set after every tick, on both engines."""
    def build(flocking):
        sim = Simulation(mk_cfg(), nodes=onprem_nodes(3, gpus=8),
                         tick_s=5, engine=engine,
                         **({"schedds": 1} if flocking else {}))
        sim.submit_jobs(0, [gpu_job(90, gpus=1) for _ in range(30)])
        sim.submit_jobs(200, [gpu_job(150, gpus=2) for _ in range(10)])
        return sim

    a, b = build(False), build(True)
    assert not a.flocking and b.flocking
    for _ in range(160):
        a.step()
        b.step()
        assert _snapshot(a) == _snapshot(b)
    assert a.queue.drained() and b.queue.drained()
    ta = sorted(j.completed_at for j in a.queue.completed_log)
    tb = sorted(j.completed_at for j in b.queue.completed_log)
    assert ta == tb


def test_negotiate_cycle_single_queue_delegates():
    """Direct unit: negotiate_cycle([q]) makes exactly the claims
    negotiate(q) would."""
    def setup():
        q, col = JobQueue(), Collector()
        for i in range(6):
            q.submit(Job(ad={"request_cpus": 1}, runtime_s=60), 0.0)
        for i in range(2):
            w = Worker(name=f"w{i}", ad={"cpus": 4},
                       start_expr=ClassAdExpr("True"))
            w.booted_at = 0.0
            col.advertise(w)
        return q, col

    qa, ca = setup()
    qb, cb = setup()
    na = ca.run_cycle(qa, 0.0)
    nb = cb.negotiate_cycle([qb], 0.0)
    assert na == nb == 6
    assert [(j.jid, j.claimed_by) for j in qa.jobs()] == \
        [(j.jid, j.claimed_by) for j in qb.jobs()]


def test_flocking_order_without_accountant():
    """Plain flocking drains schedds strictly in list order under
    scarcity: the first schedd's jobs take all capacity."""
    q0, q1, col = JobQueue(name="s0"), JobQueue(name="s1"), Collector()
    for i in range(10):
        q0.submit(Job(ad={"request_cpus": 1}, runtime_s=60), 0.0)
        q1.submit(Job(ad={"request_cpus": 1}, runtime_s=60), 0.0)
    for i in range(10):
        w = Worker(name=f"w{i}", ad={"cpus": 1},
                   start_expr=ClassAdExpr("True"))
        w.booted_at = 0.0
        col.advertise(w)
    assert col.negotiate_cycle([q0, q1], 0.0) == 10
    assert q0.n_running() == 10
    assert q1.n_running() == 0


# ---------------------------------------------------------------------------
# Provisioner deficit fix: post-negotiation idle cohorts
# ---------------------------------------------------------------------------

def _pool_with_partial_worker():
    """One 8-cpu worker already holding a 1-cpu claim (so the old
    zero-claim `unclaimed_capacity` count sees NOTHING), plus 5 idle
    1-cpu jobs the next negotiation will pack onto its free capacity."""
    cfg = mk_cfg()
    q, col = JobQueue(), Collector()
    cluster = KubeCluster([Node(name="n0",
                                capacity={"cpu": 64, "memory": 512,
                                          "disk": 1024})])
    prov = Provisioner(cfg, q, col, cluster)
    w = Worker(name="w0", ad={"cpus": 8, "memory": 64, "disk": 100},
               start_expr=cfg.start_expr())
    w.booted_at = 0.0
    col.advertise(w)
    running = Job(ad={"request_cpus": 1}, runtime_s=1e4)
    q.submit(running, 0.0)
    for _ in range(5):
        q.submit(Job(ad={"request_cpus": 1}, runtime_s=600), 0.0)
    q.claim(running.jid, w.name, 0.0)
    w.add_claim(running)
    return q, col, prov, w


def test_deficit_ignores_jobs_absorbed_by_partial_capacity():
    """Regression (double-count fix): idle jobs that the current free
    capacity will absorb in the next negotiation cycle must not be
    provisioned for — the seed formula saw 5 idle − 0 unclaimed and
    submitted 5 pods for jobs about to match the half-empty worker."""
    q, col, prov, w = _pool_with_partial_worker()
    # the old formula's inputs: demand present, zero-claim count blind
    assert q.n_idle() == 5
    assert col.unclaimed_capacity() == 0
    stats = prov.reconcile(10.0)
    assert stats.submitted == 0, \
        "provisioned for jobs the negotiator is about to match"
    # and the negotiator indeed absorbs all five
    assert col.run_cycle(q, 10.0) == 5
    assert q.n_idle() == 0


def test_deficit_still_counts_unmatchable_overflow():
    """Only what fits is subtracted: demand beyond the worker's free
    capacity still gets pods."""
    q, col, prov, w = _pool_with_partial_worker()
    for _ in range(20):   # 25 idle total now, only 7 cpus free on w
        q.submit(Job(ad={"request_cpus": 1}, runtime_s=600), 0.0)
    stats = prov.reconcile(10.0)
    assert stats.submitted == 25 - 7
    assert prov.stats.per_schedd_deficit == {"schedd": 18}


def test_preview_matches_counts_partial_capacity():
    q, col, prov, w = _pool_with_partial_worker()
    preview = col.preview([q], 10.0)
    assert sum(preview[0].values()) == 5


# ---------------------------------------------------------------------------
# Trace splitting + concurrent multi-schedd replay
# ---------------------------------------------------------------------------

def test_split_trace_is_exact_ordered_partition():
    trace = diurnal_day(600, seed=11, duration_s=7200.0)
    parts = split_trace(trace, by="group", n_schedds=3)
    assert sorted(parts) == ["schedd00", "schedd01", "schedd02"]
    assert sum(len(p) for p in parts.values()) == len(trace)
    seen = set()
    for name, part in parts.items():
        prev = -1.0
        groups = set()
        for rec in part.records:
            assert rec.arrival_s >= prev
            prev = rec.arrival_s
            groups.add(rec.group)
            seen.add(id(rec))
        for g in groups:     # a label never spans two schedds
            for other, op in parts.items():
                if other != name:
                    assert g not in {r.group for r in op.records}
    assert len(seen) == len(trace)
    # deterministic: same trace, same split
    parts2 = split_trace(trace, by="group", n_schedds=3)
    for name in parts:
        assert [r.to_obj() for r in parts[name].records] == \
            [r.to_obj() for r in parts2[name].records]


def test_split_trace_by_label_names_schedds_after_labels():
    trace = diurnal_day(300, seed=2, duration_s=3600.0)
    parts = split_trace(trace, by="group")
    assert set(parts) == {r.group for r in trace.records}


def test_replay_flock_conserves_demand():
    """Three schedds stream their sub-traces concurrently into one
    federated pool; the union completes the whole trace exactly."""
    trace = diurnal_day(400, seed=5, duration_s=7200.0)
    parts = split_trace(trace, by="group", n_schedds=3)
    sim = Simulation(mk_cfg(), schedds=list(parts), fairshare=True,
                     nodes=onprem_nodes(8, gpus=8, cpus=64), tick_s=30,
                     negotiate_interval_s=60, metrics_interval_s=300)
    reps = replay_flock(sim, parts, coalesce_s=10.0,
                        compact_completed=True)
    sim.run_until_drained(max_t=5e6)
    assert sim.drained()
    total = sum(r.stats.completed.n for r in reps.values())
    core_s = sum(r.stats.completed.core_seconds for r in reps.values())
    assert total == len(trace)
    assert core_s == pytest.approx(trace.total_core_seconds(), rel=1e-9)
    # per-schedd and per-user gauges got recorded
    assert sim.recorder.schedds_recorded() == sorted(parts)
    assert sim.recorder.users_recorded()
    for name in parts:
        assert sim.recorder.schedd_values("idle_jobs", name)
