"""Backlog-driven live fusion (ISSUE 10 tentpole part 2).

Before this change the event engine quiesced every staged cycle in the
same instant, so `negotiation_batch=K` degenerated to K=1 flushes in
live mode — `repro_fused_fallbacks_total{reason="single_cycle"}`
was 100% of flushes.  Now `_negotiate_cb` defers the flush across
provably-unobservable windows (no event, no completion, no idle-timeout
expiry before the next firing), so backlogs of 2+ cycles reach the
fused multi-cycle jit.

Pinned here:
  * engagement — a fusion-friendly cadence (negotiate 20s inside a 60s
    tick/reconcile grid) on a saturated pool accumulates real fused
    batches, and single-cycle fallbacks drop below 100% of flushes;
  * safety — deferral parks worker advancement; the flush replays it
    segment-by-segment at the staged timestamps, so claim maps, the
    recorder's Fig 2-3 gauge series, and completion logs stay
    bit-identical to `negotiation_batch=1` across K in {1,2,8}, on a
    streaming diurnal trace replay (numpy and jax backends);
  * boundaries — `run()` returns quiescent (no staged residue for
    observers), and a completion landing inside a candidate window
    vetoes deferral (the claim that would go stale is negotiated on
    time).
"""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import ProvisionerConfig, Simulation, gpu_job, onprem_nodes
from repro.core.matchmaker import HAVE_JAX
from repro.workload.generators import diurnal_day
from repro.workload.replay import replay_trace

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


def fusion_sim(batch, *, matchmaker="numpy", nodes=2):
    """negotiate every 20s inside a 60s tick/reconcile/metrics grid:
    the [20,40] windows carry no events, so deferral can engage there;
    every grid instant (reconcile, straggler, metrics) vetoes."""
    cfg = ProvisionerConfig(submit_interval_s=60, idle_timeout_s=900,
                            startup_delay_s=30, matchmaker=matchmaker,
                            negotiation_batch=batch)
    return Simulation(cfg, nodes=onprem_nodes(nodes, gpus=8), tick_s=60,
                      negotiate_interval_s=20, metrics_interval_s=60)


def fallback_counts(sim):
    fam = sim.collector._c_fallbacks
    return {k[0]: int(c.value) for k, c in fam.children.items()}


def claim_map(q):
    return sorted((j.jid, j.claimed_by, j.attempt_started_at)
                  for j in q.jobs() if j.claimed_by is not None)


def completion_signature(sim):
    return sorted((j.jid, j.submitted_at, j.runtime_s, j.completed_at)
                  for j in sim.queue.completed_log)


# -- engagement ---------------------------------------------------------------

def test_live_fusion_engages_on_saturated_pool():
    sim = fusion_sim(batch=4)
    # runtimes far beyond the horizon: no completion ever vetoes
    sim.submit_jobs(0, [gpu_job(50000.0) for _ in range(40)])
    sim.run(600)
    col = sim.collector
    assert col.fused_batches > 0, fallback_counts(sim)
    flushes = col.fused_batches + col.staged_fallbacks
    single = fallback_counts(sim).get("single_cycle", 0)
    # the pre-deferral live engine was 100% single_cycle
    assert single < flushes
    # run() hands back a quiescent simulation
    assert not col._staged_times


def test_deferral_respects_completions():
    """A claim completing inside a candidate window must veto deferral:
    the freed capacity is negotiated at the very next firing, exactly
    as in batch=1, and the completion time itself stays exact."""
    def drive(batch):
        sim = fusion_sim(batch=batch)
        # completes at boot+startup+runtime, deliberately off-grid and
        # inside a [20,40] deferral window
        sim.submit_jobs(0, [gpu_job(93.0)] + [gpu_job(50000.0)
                                              for _ in range(20)])
        sim.run(900)
        return completion_signature(sim), claim_map(sim.queue)

    sig1, cm1 = drive(1)
    sig8, cm8 = drive(8)
    assert sig1 and sig1 == sig8
    assert cm1 == cm8


# -- differential: streaming diurnal replay across K --------------------------

def _replay(batch, matchmaker):
    trace = diurnal_day(150, seed=3, duration_s=3600.0)
    cfg = ProvisionerConfig(submit_interval_s=60, idle_timeout_s=300,
                            startup_delay_s=30, matchmaker=matchmaker,
                            negotiation_batch=batch)
    sim = Simulation(cfg, nodes=onprem_nodes(2, gpus=8), tick_s=60,
                     negotiate_interval_s=20, metrics_interval_s=60)
    replay_trace(sim, trace, coalesce_s=0.0)
    sim.run_until_drained(max_t=1e6)
    return sim


@pytest.mark.parametrize("matchmaker", [
    "numpy", pytest.param("jax", marks=needs_jax)])
def test_diurnal_replay_bit_identical_across_batch(matchmaker):
    ref = _replay(1, matchmaker)
    ref_sig = completion_signature(ref)
    ref_series = ref.recorder.series
    assert ref_sig, "trace must complete jobs"
    for K in (2, 8):
        sim = _replay(K, matchmaker)
        assert completion_signature(sim) == ref_sig, f"K={K}"
        # Fig 2-3 gauge series: same sample instants, same values
        assert sim.recorder.series == ref_series, f"K={K}"
        assert not sim.collector._staged_times


def test_diurnal_replay_live_fusion_fraction():
    """On the streaming trace the quiet windows must actually fuse —
    single-cycle fallbacks are no longer 100% of flushes."""
    sim = _replay(8, "numpy")
    col = sim.collector
    assert col.fused_batches > 0, fallback_counts(sim)
    flushes = col.fused_batches + col.staged_fallbacks
    assert fallback_counts(sim).get("single_cycle", 0) < flushes
