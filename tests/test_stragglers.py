"""Straggler mitigation: slow workers get their jobs rescheduled and are
retired; makespan stays bounded."""
from repro.core import ProvisionerConfig, Simulation, gpu_job, onprem_nodes
from repro.core.stragglers import StragglerPolicy


def _run(policy):
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=10)
    sim = Simulation(cfg, nodes=onprem_nodes(4, gpus=8), tick_s=5,
                     straggler_policy=policy)
    sim.submit_jobs(0, [gpu_job(600, gpus=1) for _ in range(16)])
    # a third of the busy workers drop to 10% speed shortly after start
    sim.inject_slow_workers(120, frac=0.34, rate=0.1)
    sim.run_until_drained(max_t=40000)
    return sim


def test_stragglers_rescheduled_and_workers_retired():
    policy = StragglerPolicy(factor=1.5)
    sim = _run(policy)
    assert sim.queue.drained()
    assert policy.rescheduled >= 1
    assert policy.retired_workers >= 1
    # nothing runs on a retired straggler again
    for w in sim.all_workers:
        if w.work_rate < 1.0:
            assert w.terminated


def test_mitigation_beats_no_mitigation():
    sim_without = _run(None)
    sim_with = _run(StragglerPolicy(factor=1.5))
    assert sim_with.queue.drained() and sim_without.queue.drained()
    # slow workers at 10% speed turn a 600 s job into 6000 s without
    # mitigation; with it, the job reschedules after ~900 s
    assert sim_with.now < sim_without.now
