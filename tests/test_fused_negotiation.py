"""Fused multi-cycle negotiation (ISSUE 8 tentpole): a staged K-cycle
batch flushed through the fused jit is bit-identical — claim maps,
timestamps, free matrices — to K sequential single-cycle negotiations.

Three layers:
  * backend — `match_cycles` (one device dispatch) vs
    `sequential_match_cycles` (the K-loop reference) on random deltas;
  * collector — `stage_cycle`/`quiesce` pools vs `run_cycle` pools fed
    the identical interleaved submission stream, including the
    mid-batch quiesce, worker-churn (fingerprint) fallback, and the
    cohort reseed-hazard fallback;
  * simulation — `negotiation_batch=K` engines drain to the same claim
    map as `negotiation_batch=1`.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.classad import ClassAdExpr
from repro.core.config import load_ini, dump_ini
from repro.core.jobqueue import Job, JobQueue
from repro.core.matchmaker import HAVE_JAX, make_matchmaker
from repro.core.matchmaker.base import (
    CycleDelta, match_cycles, sequential_match_cycles,
)
from repro.core.worker import Collector, Worker

from test_matchmaker_differential import random_problem

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


# -- backend: fused K-cycle dispatch vs K-loop reference ---------------------

def random_deltas(rng, p, K):
    C, W = p.compat.shape
    deltas = []
    for _ in range(K):
        arrivals = rng.integers(0, 6, size=C).astype(np.int64)
        free_add = None
        if rng.random() < 0.5:
            free_add = np.zeros((W, p.requests.shape[1]))
            free_add[:, 0] = rng.integers(0, 5, size=W)
            free_add[:, 2] = rng.integers(0, 9, size=W)
        budget = (None if rng.random() < 0.7
                  else int(rng.integers(1, 40)))
        deltas.append(CycleDelta(arrivals=arrivals, free_add=free_add,
                                 budget=budget))
    return deltas


@needs_jax
@pytest.mark.parametrize("K", [1, 2, 8])
def test_match_cycles_bit_identical_to_sequential(K):
    jaxmm = make_matchmaker("jax")
    ref = make_matchmaker("numpy")
    rng = np.random.default_rng(100 + K)
    for trial in range(8):
        p = random_problem(rng)
        p.demand = np.zeros_like(p.demand)     # arrivals carry the demand
        deltas = random_deltas(rng, p, K)
        fused = jaxmm.match_cycles(p, deltas)
        seq_jax = sequential_match_cycles(jaxmm, p, deltas)
        seq_np = match_cycles(ref, p, deltas)  # dispatcher -> sequential
        assert len(fused) == len(seq_jax) == len(seq_np) == K
        for k in range(K):
            np.testing.assert_array_equal(
                fused[k].takes, seq_jax[k].takes,
                err_msg=f"trial={trial} cycle={k} (vs sequential jax)")
            np.testing.assert_array_equal(
                fused[k].free_after, seq_jax[k].free_after,
                err_msg=f"trial={trial} cycle={k} free")
            np.testing.assert_array_equal(
                fused[k].takes, seq_np[k].takes,
                err_msg=f"trial={trial} cycle={k} (vs numpy)")


# -- collector: staged batches vs interleaved sequential cycles --------------

def mk_pool(batch, n_workers=10, cpus=8, matchmaker="jax"):
    col = Collector(matchmaker=matchmaker, negotiation_batch=batch)
    for i in range(n_workers):
        w = Worker(name=f"w{i}", ad={"cpus": cpus, "memory": 64},
                   start_expr=ClassAdExpr("True"))
        w.booted_at = 0.0
        col.advertise(w)
    return col, JobQueue()


def submit_wave(q, t, n, cpus=1, mem=4, user="alice"):
    for _ in range(n):
        q.submit(Job(ad={"request_cpus": cpus, "request_memory": mem,
                         "owner": user, "runtime_s": 1e5}), now=t)


def full_claim_map(q):
    return sorted((j.jid, j.claimed_by, j.attempt_started_at)
                  for j in q.jobs() if j.claimed_by is not None)


@needs_jax
@pytest.mark.parametrize("K", [1, 2, 8])
def test_staged_flush_identical_to_sequential(K):
    """Random interleaved waves: whatever mix of fused batches and
    fallbacks the guards pick, the claim map (including the per-claim
    timestamps) must equal the cycle-by-cycle reference."""
    rng = np.random.default_rng(7 + K)
    for trial in range(6):
        col_s, q_s = mk_pool(batch=K)
        col_r, q_r = mk_pool(batch=1)
        times = [10.0 * (k + 1) for k in range(K)]
        waves = [(int(rng.integers(0, 20)), int(rng.integers(1, 4)),
                  ["alice", "bob"][int(rng.integers(0, 2))])
                 for _ in times]
        claims_s = 0
        for t, (n, c, u) in zip(times, waves):
            submit_wave(q_s, t - 1, n, cpus=c, user=u)
            claims_s += col_s.stage_cycle(q_s, t)
        claims_s += col_s.quiesce()
        claims_r = 0
        for t, (n, c, u) in zip(times, waves):
            submit_wave(q_r, t - 1, n, cpus=c, user=u)
            claims_r += col_r.run_cycle(q_r, t)
        assert claims_s == claims_r, f"K={K} trial={trial}"
        assert full_claim_map(q_s) == full_claim_map(q_r), \
            f"K={K} trial={trial}"


@needs_jax
def test_staged_batch_takes_fused_path_on_disjoint_waves():
    """Waves of fresh cohort shapes never re-seed a drained cohort, so
    the batch must go through the fused jit (not the fallback) and
    still match the sequential reference exactly."""
    K = 4
    col_s, q_s = mk_pool(batch=K, n_workers=4, cpus=4)
    col_r, q_r = mk_pool(batch=1, n_workers=4, cpus=4)
    times = [10.0 * (k + 1) for k in range(K)]
    for q, col, stage in ((q_s, col_s, True), (q_r, col_r, False)):
        for k, t in enumerate(times):
            submit_wave(q, t - 1, 8, cpus=2, mem=4 + 8 * k)  # new shape/wave
            if stage:
                col.stage_cycle(q, t)
            else:
                col.run_cycle(q, t)
    col_s.quiesce()
    assert col_s.fused_batches == 1 and col_s.staged_fallbacks == 0
    assert col_s.fused_cycles == K
    assert full_claim_map(q_s) == full_claim_map(q_r)


@needs_jax
def test_mid_batch_quiesce_flushes_and_matches():
    """An external op mid-batch (snapshot, reconfig, ...) quiesces a
    half-full staging buffer; the partial flush plus the follow-on
    cycles still replay the sequential reference bit-for-bit."""
    K = 8
    col_s, q_s = mk_pool(batch=K, n_workers=4, cpus=4)
    col_r, q_r = mk_pool(batch=1, n_workers=4, cpus=4)
    times = [10.0 * (k + 1) for k in range(5)]
    for k, t in enumerate(times[:3]):
        submit_wave(q_s, t - 1, 5, cpus=2, mem=4 + 8 * k)
        col_s.stage_cycle(q_s, t)
    col_s.quiesce()                      # external op: flush 3 of 8
    assert not col_s._staged_times
    for k, t in enumerate(times[3:], start=3):
        submit_wave(q_s, t - 1, 5, cpus=2, mem=4 + 8 * k)
        col_s.stage_cycle(q_s, t)
    col_s.quiesce()
    for k, t in enumerate(times):
        submit_wave(q_r, t - 1, 5, cpus=2, mem=4 + 8 * k)
        col_r.run_cycle(q_r, t)
    assert full_claim_map(q_s) == full_claim_map(q_r)


@needs_jax
def test_worker_churn_mid_batch_forces_fallback():
    """A worker booting between staged cycles changes the pool
    fingerprint — the batch must replay sequentially (the fused problem
    would give the newcomer to cycles that predate it) and match the
    reference, which sees the worker only from its boot time."""
    col_s, q_s = mk_pool(batch=4, n_workers=2, cpus=4)
    col_r, q_r = mk_pool(batch=1, n_workers=2, cpus=4)
    times = [10.0, 20.0, 30.0, 40.0]

    def boot_extra(col):
        w = Worker(name="late", ad={"cpus": 4, "memory": 64},
                   start_expr=ClassAdExpr("True"))
        w.booted_at = 15.0
        col.advertise(w)

    for k, t in enumerate(times):
        submit_wave(q_s, t - 1, 6, cpus=2, mem=4 + 8 * k)
        col_s.stage_cycle(q_s, t)
        if t == 10.0:
            boot_extra(col_s)
    col_s.quiesce()
    for k, t in enumerate(times):
        submit_wave(q_r, t - 1, 6, cpus=2, mem=4 + 8 * k)
        col_r.run_cycle(q_r, t)
        if t == 10.0:
            boot_extra(col_r)
    assert col_s.staged_fallbacks == 1 and col_s.fused_batches == 0
    assert full_claim_map(q_s) == full_claim_map(q_r)


@needs_jax
def test_reseed_hazard_forces_fallback():
    """A cohort that fully drains mid-batch and then receives new
    arrivals would re-seed its FIFO sort key in the sequential path —
    the guard must detect it from the fused plans and replay
    sequentially, exactly."""
    col_s, q_s = mk_pool(batch=3, n_workers=10, cpus=8)
    col_r, q_r = mk_pool(batch=1, n_workers=10, cpus=8)
    times = [10.0, 20.0, 30.0]
    waves = [(4, 3, "alice"), (1, 1, "bob"), (13, 3, "alice")]
    for (t, (n, c, u)) in zip(times, waves):
        submit_wave(q_s, t - 1, n, cpus=c, user=u)
        col_s.stage_cycle(q_s, t)
    col_s.quiesce()
    for (t, (n, c, u)) in zip(times, waves):
        submit_wave(q_r, t - 1, n, cpus=c, user=u)
        col_r.run_cycle(q_r, t)
    assert col_s.staged_fallbacks == 1
    assert full_claim_map(q_s) == full_claim_map(q_r)


def test_noop_memo_skips_unchanged_cycles():
    """Idle cycles with no queue or pool change hit the no-op memo; any
    idle-set or claim change invalidates it."""
    col, q = mk_pool(batch=1, matchmaker="numpy")
    submit_wave(q, 0.0, 80, cpus=2)      # exceeds the 10x8-cpu pool
    col.run_cycle(q, 1.0)                # claims 40, pool exhausts
    col.run_cycle(q, 2.0)                # claims 0 -> memo armed
    base = col.noop_hits
    col.run_cycle(q, 3.0)
    col.run_cycle(q, 4.0)
    assert col.noop_hits == base + 2
    submit_wave(q, 4.5, 1, cpus=2)       # idle set changed -> memo stale
    col.run_cycle(q, 5.0)
    assert col.noop_hits == base + 2


# -- simulation: negotiation_batch=K engines match batch=1 -------------------

@needs_jax
def test_simulation_batch_knob_preserves_claim_map():
    from repro.core import (
        ProvisionerConfig, Simulation, gpu_job, onprem_nodes,
    )

    def drive(batch):
        cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                                startup_delay_s=30, matchmaker="jax",
                                negotiation_batch=batch)
        sim = Simulation(cfg, nodes=onprem_nodes(4, gpus=8), tick_s=5)
        sim.submit_jobs(0, [gpu_job(300) for _ in range(12)])
        sim.run(3000)
        return sim, full_claim_map(sim.queue)

    sim1, cm1 = drive(1)
    sim4, cm4 = drive(4)
    assert sim1.queue.drained() and sim4.queue.drained()
    assert cm1 == cm4


# -- config plumbing ---------------------------------------------------------

def test_negotiation_batch_ini_roundtrip():
    cfg = load_ini("[provision]\nnegotiation_batch=8\n")
    assert cfg.negotiation_batch == 8
    assert "negotiation_batch=8" in dump_ini(cfg)
    cfg2 = load_ini(dump_ini(cfg))
    assert cfg2.negotiation_batch == 8


def test_negotiation_batch_default_is_one():
    cfg = load_ini("[provision]\n")
    assert cfg.negotiation_batch == 1
