"""Data-pipeline determinism + optimizer correctness + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.data.pipeline import SyntheticTokenPipeline
from repro.train.optimizer import (
    OptimizerConfig, adamw_init, adamw_update, global_norm,
)
from repro.train.schedule import lr_schedule


def test_pipeline_deterministic_across_restarts():
    """Same (seed, step) -> byte-identical batch: the preemption-exactness
    property the provisioner fault model relies on."""
    p1 = SyntheticTokenPipeline(1000, 64, 4, seed=7)
    p2 = SyntheticTokenPipeline(1000, 64, 4, seed=7)
    for step in (0, 3, 10_000):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_pipeline_steps_differ():
    p = SyntheticTokenPipeline(1000, 64, 4, seed=7)
    assert not np.array_equal(p.batch_at(0)["tokens"],
                              p.batch_at(1)["tokens"])


def test_labels_are_shifted_tokens():
    p = SyntheticTokenPipeline(1000, 64, 2, seed=0)
    b = p.batch_at(0)
    # label[t] is the next token: reconstructed stream consistency
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_adamw_matches_manual_reference(rng):
    """One AdamW step vs a hand-computed update."""
    cfg = OptimizerConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                          weight_decay=0.0, grad_clip=1e9)
    p = {"w": jnp.asarray(rng.standard_normal((5,)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((5,)), jnp.float32)}
    state = adamw_init(p, cfg)
    new_p, new_state, _ = adamw_update(p, g, state, cfg, jnp.float32(1e-2))

    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expect = np.asarray(p["w"]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)
    assert int(new_state["count"]) == 1


def test_grad_clip_caps_update_norm(rng):
    cfg = OptimizerConfig(lr=1.0, grad_clip=0.5, weight_decay=0.0)
    p = {"w": jnp.zeros((10,), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((10,)) * 100, jnp.float32)}
    state = adamw_init(p, cfg)
    _, _, metrics = adamw_update(p, g, state, cfg, jnp.float32(1.0))
    assert float(metrics["grad_norm"]) > 0.5
    assert float(metrics["clip_factor"]) < 1.0


def test_bf16_state_policy(rng):
    cfg = OptimizerConfig(state_dtype="bfloat16", keep_nu_fp32=True)
    p = {"w": jnp.zeros((4,), jnp.bfloat16)}
    st_ = adamw_init(p, cfg)
    assert st_["mu"]["w"].dtype == jnp.bfloat16
    assert st_["nu"]["w"].dtype == jnp.float32


@settings(max_examples=30, deadline=None)
@given(step=st.integers(0, 20_000))
def test_lr_schedule_bounds(step):
    lr = float(lr_schedule(jnp.asarray(step), peak=3e-4, warmup_steps=100,
                           total_steps=10_000, min_ratio=0.1))
    assert 0.0 <= lr <= 3e-4 + 1e-9
    if step >= 10_000:
        np.testing.assert_allclose(lr, 3e-5, rtol=1e-3)
