"""Batched-preview differential: `preview_many` on the jax backend is
bit-identical to the sequential numpy reference (ISSUE 10 tentpole).

The batched path evaluates N INDEPENDENT candidate pools in one jitted
vmap dispatch — no drain guard, device-resident cohort constants —
so every layer that could diverge from the per-candidate loop gets a
pin here:

  * random problems (integer and fractional requests) for N in {1,2,8};
  * per-candidate demand overrides;
  * the `session=` device-constant cache, including reuse across calls
    and invalidation when the cohort processing `order` changes under
    an unchanged session token;
  * padding-bucket edges (chunk and lane boundaries);
  * the base-module dispatcher falling back to the sequential loop for
    backends without a vectorised implementation.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from test_matchmaker_differential import random_problem

from repro.core.matchmaker import (
    HAVE_JAX, NumpyMatchmaker, make_matchmaker,
)
from repro.core.matchmaker.base import preview_many, sequential_preview_many

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


def random_frees(rng, p, n):
    """N candidate pools shaped like the problem's, scaled/perturbed so
    candidates genuinely differ (including an all-zeros pool)."""
    out = []
    for i in range(n):
        f = p.free * rng.choice([0.0, 0.5, 1.0, 2.0], size=(p.n_workers, 1))
        out.append(np.ascontiguousarray(f))
    return out


def assert_batches_equal(got, want, label):
    assert len(got) == len(want), label
    for i, (g, w) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w), err_msg=f"{label} cand={i}")


@needs_jax
@pytest.mark.parametrize("fractional", [False, True])
def test_preview_many_matches_sequential_numpy(fractional):
    jaxmm = make_matchmaker("jax")
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(101 + fractional)
    for trial in range(15):
        p = random_problem(rng, fractional=fractional)
        for n in (1, 2, 8):
            frees = random_frees(rng, p, n)
            want = sequential_preview_many(ref, p, frees)
            got = jaxmm.preview_many(p, frees)
            assert_batches_equal(
                got, want, f"trial={trial} n={n} fractional={fractional}")


@needs_jax
def test_preview_many_per_candidate_demands():
    jaxmm = make_matchmaker("jax")
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(113)
    for trial in range(10):
        p = random_problem(rng)
        n = int(rng.integers(1, 9))
        frees = random_frees(rng, p, n)
        demands = [rng.integers(0, 40, size=p.n_cohorts).astype(np.int64)
                   for _ in range(n)]
        want = sequential_preview_many(ref, p, frees, demands)
        got = jaxmm.preview_many(p, frees, demands)
        assert_batches_equal(got, want, f"trial={trial} n={n}")


@needs_jax
def test_preview_many_session_reuse_and_order_invalidation():
    """A stable session token keeps cohort constants on device across
    calls; results must stay identical to fresh dispatches, and a
    changed processing order under the SAME token must be detected (the
    session validates `problem.order`, not just the token)."""
    jaxmm = make_matchmaker("jax")
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(127)
    p = random_problem(rng, C=37, W=21)
    token = ("pool", "fingerprint")
    for call in range(4):
        frees = random_frees(rng, p, 3)
        want = sequential_preview_many(ref, p, frees)
        got = jaxmm.preview_many(p, frees, session=token)
        assert_batches_equal(got, want, f"session call={call}")
    # same token, permuted order: constants must be rebuilt
    p2 = random_problem(rng, C=37, W=21)
    p2.order = np.roll(p.order, 5)
    p2.requests = p.requests
    p2.demand = p.demand
    p2.free = p.free
    p2.compat = p.compat
    frees = random_frees(rng, p2, 2)
    want = sequential_preview_many(ref, p2, frees)
    got = jaxmm.preview_many(p2, frees, session=token)
    assert_batches_equal(got, want, "order change under stable token")


@needs_jax
def test_preview_many_padding_boundaries():
    jaxmm = make_matchmaker("jax")
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(131)
    for C in (1, 63, 64, 65):
        for W in (1, 127, 128, 129):
            p = random_problem(rng, C=C, W=W)
            frees = random_frees(rng, p, 2)
            want = sequential_preview_many(ref, p, frees)
            got = jaxmm.preview_many(p, frees)
            assert_batches_equal(got, want, f"C={C} W={W}")


@needs_jax
def test_preview_many_marks_preview_call():
    """The backend self-reports the dedicated preview entry path (the
    profiler's path-labelled jit counter reads this)."""
    jaxmm = make_matchmaker("jax")
    rng = np.random.default_rng(137)
    p = random_problem(rng)
    jaxmm.preview_many(p, [p.free])
    assert jaxmm.last_call["kind"] == "preview"
    assert "compiled" in jaxmm.last_call


@needs_jax
def test_dispatcher_routes_jax_and_falls_back_sequential():
    rng = np.random.default_rng(139)
    p = random_problem(rng)
    frees = random_frees(rng, p, 4)
    ref = NumpyMatchmaker()
    want = sequential_preview_many(ref, p, frees)
    # numpy has no vectorised preview: the dispatcher must loop
    assert_batches_equal(preview_many(ref, p, frees), want, "numpy route")
    assert_batches_equal(preview_many(make_matchmaker("jax"), p, frees),
                         want, "jax route")
