"""Optional-hypothesis shim: property tests degrade to skips when the
`hypothesis` package is absent (e.g. minimal CI images), instead of
breaking collection for the whole module.

Usage in test modules::

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed "
                       "(pip install -r requirements-dev.txt)")(fn)
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stands in for `hypothesis.strategies`: any strategy call at
        decoration time returns an inert placeholder (the test body never
        runs — `given` already skipped it)."""

        def __getattr__(self, name):
            def strategy(*_a, **_k):
                return None
            return strategy

    st = _Strategies()
