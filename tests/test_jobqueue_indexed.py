"""Indexed JobQueue invariants (hypothesis property tests).

The queue keeps three views of the same jobs — the flat registry,
per-state buckets, and idle cohorts.  Arbitrary submit/claim/release/
complete sequences must keep them consistent, keep `preempt_count` /
`wasted_s` monotone, and keep checkpoint-truncated restart accounting
exact."""
import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import Job, JobQueue, JobState
from repro.core.jobqueue import cohort_key_of

AD_CHOICES = [
    {"request_cpus": 1, "request_gpus": 1, "request_memory": 4},
    {"request_cpus": 2, "request_gpus": 0, "request_memory": 8},
    {"request_cpus": 1, "request_gpus": 1, "request_memory": 4,
     "arch": "gpu"},
    {"request_cpus": 4, "request_gpus": 2, "request_memory": 16,
     "checkpoint_interval_s": 30.0},
]

# an op is (kind, job_selector, dt) — selectors index into live jobs
OPS = st.lists(
    st.tuples(
        st.sampled_from(["submit", "claim", "run", "release", "complete"]),
        st.integers(0, 7),
        st.floats(1.0, 200.0),
    ),
    min_size=1, max_size=60,
)


def check_indexes(q: JobQueue):
    """Every index agrees with the flat registry."""
    by_state: dict = {}
    for j in q.jobs():
        by_state.setdefault(j.state, []).append(j.jid)
    for state in JobState:
        want = sorted(by_state.get(state, []))
        got = sorted(j.jid for j in q.jobs(state))
        assert got == want, (state, got, want)
    assert q.n_idle() == len(by_state.get(JobState.IDLE, []))
    assert q.n_running() == len(by_state.get(JobState.RUNNING, []))
    # cohorts partition the idle set, and members share the key
    seen = []
    for key, jobs in q.idle_cohorts():
        assert jobs, "empty cohort left in index"
        for j in jobs.values():
            assert j.state == JobState.IDLE
            assert j.cohort_key == key == cohort_key_of(j)
            seen.append(j.jid)
    assert sorted(seen) == sorted(by_state.get(JobState.IDLE, []))
    # sorted-view really is FIFO
    for key, _jobs in q.idle_cohorts():
        order = [(j.submitted_at, j.jid) for j in q.cohort_jobs_sorted(key)]
        assert order == sorted(order)


@settings(max_examples=80, deadline=None)
@given(OPS)
def test_random_lifecycles_preserve_queue_invariants(ops):
    q = JobQueue()
    now = 0.0
    monotone: dict[int, tuple[int, float]] = {}  # jid -> (preempts, wasted)
    for kind, sel, dt in ops:
        now += 1.0
        live = q.jobs()
        if kind == "submit" or not live:
            ad = dict(AD_CHOICES[sel % len(AD_CHOICES)])
            q.submit(Job(ad=ad, runtime_s=60.0 + sel * 10), now)
        else:
            j = live[sel % len(live)]
            if kind == "claim" and j.state == JobState.IDLE:
                q.claim(j.jid, f"w{sel}", now)
            elif kind == "run" and j.state == JobState.RUNNING:
                j.remaining_s = max(0.0, j.remaining_s - dt)
            elif kind == "release" and j.state == JobState.RUNNING:
                q.release(j.jid, now, preempted=True)
            elif kind == "complete" and j.state == JobState.RUNNING:
                q.complete(j.jid, now)
        check_indexes(q)
        for j in q.jobs() + q.completed_log:
            prev = monotone.get(j.jid, (0, 0.0))
            assert j.preempt_count >= prev[0]
            assert j.wasted_s >= prev[1] - 1e-9
            assert j.remaining_s <= j.runtime_s + 1e-9
            monotone[j.jid] = (j.preempt_count, j.wasted_s)


@settings(max_examples=60, deadline=None)
@given(st.floats(10.0, 500.0), st.floats(5.0, 120.0), st.floats(0.0, 1.0))
def test_checkpoint_truncated_restart_accounting(runtime, ckpt, frac):
    """Releasing a job that did `done` work keeps only whole checkpoint
    intervals: remaining == runtime - floor(done/ckpt)*ckpt, and the tail
    past the last boundary is counted as waste."""
    q = JobQueue()
    q.submit(Job(ad={"request_gpus": 1, "checkpoint_interval_s": ckpt},
                 runtime_s=runtime), 0.0)
    (j,) = q.idle_jobs()
    q.claim(j.jid, "w0", 0.0)
    done = runtime * frac
    j.remaining_s = runtime - done
    q.release(j.jid, 100.0, preempted=True)
    kept = (done // ckpt) * ckpt
    assert j.state == JobState.IDLE
    assert j.preempt_count == 1
    assert abs(j.remaining_s - (runtime - kept)) < 1e-9
    assert abs(j.wasted_s - (done - kept)) < 1e-9
    check_indexes(q)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 30), st.integers(0, 29))
def test_release_returns_job_to_its_cohort(n, pick):
    q = JobQueue()
    for i in range(n):
        q.submit(Job(ad={"request_gpus": 1}, runtime_s=50), float(i))
    target = q.idle_jobs()[pick % n]
    q.claim(target.jid, "w0", 40.0)
    assert q.n_idle() == n - 1
    q.release(target.jid, 50.0)
    assert q.n_idle() == n
    # FIFO restored: the released (older) job sorts back to its slot
    (key,) = [k for k, _ in q.idle_cohorts()]
    order = [j.jid for j in q.cohort_jobs_sorted(key)]
    assert order == sorted(order)
    check_indexes(q)


def test_cohort_keys_separate_on_requirements_and_ads():
    q = JobQueue()
    a = Job(ad={"request_gpus": 1}, runtime_s=10)
    b = Job(ad={"request_gpus": 1}, runtime_s=10)
    from repro.core.classad import ClassAdExpr
    c = Job(ad={"request_gpus": 1}, runtime_s=10,
            requirements=ClassAdExpr("gpus >= 1"))
    d = Job(ad={"request_gpus": 2}, runtime_s=10)
    for j in (a, b, c, d):
        q.submit(j, 0.0)
    assert a.cohort_key == b.cohort_key          # identical matchmaking
    assert a.cohort_key != c.cohort_key          # requirements differ
    assert a.cohort_key != d.cohort_key          # ad differs
    assert len(dict(q.idle_cohorts())) == 3


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_shim_active():
    assert HAVE_HYPOTHESIS
