"""Trace schema: round-trips, validation, generator determinism."""
import json

import pytest

from repro.core.classad import symmetric_match
from repro.workload.generators import (
    OSG_KINDS, arrival_times, diurnal_day, diurnal_profile,
    lognormal_runtimes, pareto_runtimes, poisson_arrivals, synthesize,
    uniform_burst, zipf_users,
)
from repro.workload.trace import (
    FIELDS, Trace, TraceError, TraceRecord, iter_jsonl,
)

import numpy as np


def small_trace() -> Trace:
    return diurnal_day(200, seed=11, duration_s=7200)


# -- round-trips -------------------------------------------------------------

def test_jsonl_round_trip_is_identity():
    t = small_trace()
    text = t.to_jsonl()
    t2 = Trace.from_jsonl(text)
    assert t2.meta == t.meta               # meta rides the first line
    assert t2.to_jsonl() == text
    assert len(t2) == len(t)
    assert t2.records == t.records


def test_jsonl_meta_survives_file_round_trip(tmp_path):
    t = small_trace()
    path = str(tmp_path / "t.jsonl")
    t.save(path)
    t2 = Trace.load(path)
    assert t2.meta == t.meta
    assert t2.records == t.records
    assert t2.to_jsonl() == t.to_jsonl()


def test_csv_round_trip_is_identity(tmp_path):
    t = small_trace()
    text = t.to_csv()
    t2 = Trace.from_csv(text)
    assert t2.to_csv() == text
    assert t2.records == t.records
    path = str(tmp_path / "t.csv")
    t.save(path)
    assert Trace.load(path).records == t.records


def test_csv_and_jsonl_agree():
    t = small_trace()
    assert Trace.from_csv(t.to_csv()).records == \
        Trace.from_jsonl(t.to_jsonl()).records


def test_iter_jsonl_streams_and_skips_meta():
    t = small_trace()
    lines = t.to_jsonl().splitlines()
    assert "__trace_meta__" in lines[0]
    streamed = list(iter_jsonl(iter(lines)))
    assert streamed == t.records


# -- validation --------------------------------------------------------------

def test_out_of_order_arrivals_rejected():
    recs = [TraceRecord(arrival_s=10, runtime_s=5),
            TraceRecord(arrival_s=5, runtime_s=5)]
    with pytest.raises(TraceError, match="arrival-ordered"):
        Trace.from_records(recs)


def test_bad_records_rejected():
    with pytest.raises(TraceError):
        TraceRecord(arrival_s=-1, runtime_s=5).validate()
    with pytest.raises(TraceError):
        TraceRecord(arrival_s=0, runtime_s=0).validate()
    with pytest.raises(TraceError):
        TraceRecord(arrival_s=0, runtime_s=5, cpus=0).validate()
    with pytest.raises(TraceError, match="Requirements"):
        TraceRecord(arrival_s=0, runtime_s=5,
                    requirements="__import__('os')").validate()


def test_bad_csv_header_rejected():
    with pytest.raises(TraceError, match="header"):
        Trace.from_csv("nope,nope\n1,2\n")


def test_fields_schema_stable():
    # serialization order is a compatibility contract
    assert FIELDS == ("arrival_s", "runtime_s", "cpus", "gpus",
                      "memory_gb", "disk_gb", "requirements", "group",
                      "user", "attrs")


# -- job mapping -------------------------------------------------------------

def test_to_job_maps_ad_and_requirements():
    rec = TraceRecord(arrival_s=0, runtime_s=60, cpus=4, gpus=1,
                      memory_gb=16, requirements="arch == 'gpu'",
                      group="gpu", user="user03",
                      attrs={"arch": "gpu"})
    job = rec.to_job()
    assert job.ad["request_cpus"] == 4
    assert job.ad["request_gpus"] == 1
    assert job.ad["accounting_group"] == "gpu"
    assert job.ad["arch"] == "gpu"
    assert job.requirements is not None
    # a matching slot ad satisfies both sides of the negotiation
    offer = {"cpus": 4, "gpus": 1, "memory": 16, "disk": 8, "arch": "gpu"}
    assert symmetric_match(job.ad, offer, job.requirements, None)
    offer_cpu = {"cpus": 4, "gpus": 0, "memory": 16, "disk": 8}
    assert not symmetric_match(job.ad, offer_cpu, job.requirements, None)


def test_cohort_mix_matches_queue_cohorts():
    from repro.core.jobqueue import JobQueue, cohort_key_of
    t = small_trace()
    mix = t.cohort_mix()
    assert sum(mix.values()) == len(t)
    q = JobQueue()
    for rec in t.records:
        q.submit(rec.to_job(), rec.arrival_s)
    assert q.n_idle_cohorts() == len(mix)
    # the preview key IS the queue's cohort key
    rec = t.records[0]
    assert rec.cohort_key() == cohort_key_of(rec.to_job())


# -- generators --------------------------------------------------------------

def test_generator_determinism_same_seed_same_bytes():
    a = diurnal_day(500, seed=42, duration_s=14400)
    b = diurnal_day(500, seed=42, duration_s=14400)
    assert a.to_jsonl() == b.to_jsonl()
    assert a.to_csv() == b.to_csv()


def test_generator_different_seeds_differ():
    a = diurnal_day(500, seed=1, duration_s=14400)
    b = diurnal_day(500, seed=2, duration_s=14400)
    assert a.to_jsonl() != b.to_jsonl()


def test_exact_job_count_and_validity():
    for n in (1, 7, 500):
        t = synthesize(n, 7200, seed=5)
        assert len(t) == n
        t.validate()


def test_uniform_burst_single_cohort():
    t = uniform_burst(50, runtime_s=300)
    assert len(t.cohort_mix()) == 1
    assert t.records[0].arrival_s == 0.0


def test_diurnal_mix_is_heterogeneous():
    t = small_trace()
    assert len(t.cohort_mix()) > 10
    groups = {r.group for r in t.records}
    assert groups <= {k.name for k in OSG_KINDS}
    assert len(groups) >= 3


def test_arrival_processes():
    rng = np.random.default_rng(0)
    ts = arrival_times(rng, 1000, 3600.0, diurnal_profile())
    assert len(ts) == 1000
    assert (np.diff(ts) >= 0).all()
    assert 0 <= ts[0] and ts[-1] < 3600.0
    ps = poisson_arrivals(np.random.default_rng(0), 1.0, 600.0)
    assert (np.diff(ps) > 0).all() and ps[-1] < 600.0
    # rate 1/s over 600s: count should be in the right ballpark
    assert 450 < len(ps) < 750


def test_runtime_models_heavy_tailed():
    rng = np.random.default_rng(0)
    ln = lognormal_runtimes(rng, 5000, 600.0, 1.0)
    assert (ln >= 1.0).all()
    assert np.median(ln) == pytest.approx(600.0, rel=0.15)
    pa = pareto_runtimes(np.random.default_rng(0), 5000, 60.0, 1.5,
                         cap_s=86400.0)
    assert (pa >= 60.0).all() and (pa <= 86400.0).all()
    assert np.mean(pa) > np.median(pa)      # right-skewed


def test_zipf_users_skewed():
    u = zipf_users(np.random.default_rng(0), 5000, 20)
    counts = np.bincount(u, minlength=20)
    assert counts[0] > counts[10]


def test_trace_stats_totals():
    t = small_trace()
    s = t.stats()
    assert s["n"] == len(t)
    assert s["core_seconds"] == pytest.approx(
        sum(r.cpus * r.runtime_s for r in t.records))
    assert json.dumps(s)                     # JSON-serializable
