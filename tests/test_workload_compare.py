"""Policy-comparison harness: document shape, conservation, CLI."""
import json

import pytest

from repro.workload.compare import (
    PolicySpec, compare, comparison_table, standard_policies,
    standard_policy,
)
from repro.workload.generators import synthesize


def tiny_trace(n=300, seed=21):
    return synthesize(n, 3600.0, seed=seed, burst_frac=0.2, n_bursts=2)


def fast(spec: PolicySpec) -> PolicySpec:
    spec.tick_s = 15.0
    spec.negotiate_interval_s = 30.0
    spec.metrics_interval_s = 120.0
    return spec


def test_compare_two_policies_document_shape():
    trace = tiny_trace()
    policies = [fast(p) for p in
                standard_policies(("fill-first", "cheapest-first"))]
    doc = compare(trace, policies, coalesce_s=10.0)

    assert set(doc) == {"trace", "replay", "policies", "conservation"}
    assert set(doc["policies"]) == {"fill-first", "cheapest-first"}
    for r in doc["policies"].values():
        assert r["jobs"]["n"] == len(trace)
        assert {"idle_jobs", "running_jobs", "provisioned_cores",
                "live_nodes", "cost_rate", "idle_cohorts"} <= \
            set(r["series"])
        for key, s in r["series"].items():
            assert len(s["t"]) == len(s["v"])
        assert r["makespan_s"] > 0
        assert "p95_wait_s" in r["jobs"]
        assert "onprem" in r["backends"]
    c = doc["conservation"]
    assert c["ok"] is True
    assert c["policies_agree"] is True
    assert c["matches_trace"] is True
    assert c["jobs_completed"] == [len(trace), len(trace)]
    json.dumps(doc)                        # fully JSON-serializable


def test_conservation_totals_match_trace():
    trace = tiny_trace(150, seed=5)
    doc = compare(trace, [fast(standard_policy("fill-first"))],
                  coalesce_s=10.0)
    c = doc["conservation"]
    assert c["trace_jobs"] == 150
    assert c["core_hours"][0] == pytest.approx(
        trace.total_core_seconds() / 3600.0, abs=1e-4)  # 4-decimal JSON


def test_nap_headroom_grid_names():
    grid = standard_policies(("cheapest-first",), headrooms=(8, 24))
    assert [p.name for p in grid] == ["cheapest-first/nap8",
                                      "cheapest-first/nap24"]
    assert "max_nodes=8" in grid[0].ini
    assert "max_nodes=24" in grid[1].ini


def test_duplicate_policy_names_rejected():
    trace = tiny_trace(50)
    ps = standard_policies(("fill-first", "fill-first"))
    with pytest.raises(ValueError, match="duplicate"):
        compare(trace, ps)


def test_truncated_compare_skips_trace_totals():
    trace = tiny_trace(120, seed=8)
    doc = compare(trace, [fast(standard_policy("fill-first"))],
                  coalesce_s=10.0, until_s=1800.0)
    c = doc["conservation"]
    assert "matches_trace" not in c
    assert c["ok"] is True
    n = doc["policies"]["fill-first"]["jobs"]["n"]
    assert 0 < n < 120


def test_comparison_table_renders():
    trace = tiny_trace(80, seed=2)
    doc = compare(trace, [fast(p) for p in
                          standard_policies(("fill-first",))],
                  coalesce_s=10.0)
    table = comparison_table(doc)
    assert "fill-first" in table
    assert "conservation: ok=True" in table


def test_cli_generate_and_compare(tmp_path):
    from repro.workload.__main__ import main
    trace_path = str(tmp_path / "t.jsonl")
    out_path = str(tmp_path / "cmp.json")
    assert main(["generate", "--preset", "diurnal", "--jobs", "150",
                 "--seed", "4", "--duration-s", "3600",
                 "--out", trace_path]) == 0
    assert main(["compare", trace_path,
                 "--policies", "fill-first,cheapest-first",
                 "--coalesce-s", "15", "--out", out_path]) == 0
    with open(out_path) as f:
        doc = json.load(f)
    assert doc["conservation"]["ok"] is True
    assert set(doc["policies"]) == {"fill-first", "cheapest-first"}


def test_cli_budget_failure(tmp_path):
    from repro.workload.__main__ import main
    rc = main(["compare", "--generate", "diurnal", "--jobs", "100",
               "--duration-s", "1800", "--seed", "1",
               "--policies", "fill-first", "--budget-s", "0.0"])
    assert rc == 2
