"""Continuous-batching serve engine: drains, batches, greedy-consistent."""
import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import model as model_lib
from repro.models.param import materialize
from repro.serve.engine import Request, ServeEngine


def _engine(arch="qwen2-1.5b", slots=3, max_seq=96, seed=0):
    cfg = reduced_config(arch)
    params = materialize(model_lib.init_model(cfg), jax.random.PRNGKey(seed))
    return cfg, params, ServeEngine(cfg, params, batch_slots=slots,
                                    max_seq=max_seq)


def test_engine_drains_all_requests(rng):
    cfg, params, eng = _engine()
    for i in range(7):  # more requests than slots -> queueing
        prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=4))
    assert eng.queue_depth() == 7
    ticks = eng.run_until_drained(max_ticks=500)
    assert ticks < 500
    assert len(eng.done) == 7
    for r in eng.done.values():
        assert len(r.output) == 4


def test_batched_output_matches_solo_output(rng):
    """A request decoded alongside others must produce the same greedy
    tokens as the same request decoded alone (continuous batching must
    not leak state across slots)."""
    prompts = [rng.integers(0, 100, size=6).astype(np.int32)
               for _ in range(3)]

    cfg, params, eng_multi = _engine(slots=3, seed=1)
    for i, p in enumerate(prompts):
        eng_multi.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    eng_multi.run_until_drained()

    solo_outputs = []
    for i, p in enumerate(prompts):
        cfg2, params2, eng_solo = _engine(slots=1, seed=1)
        eng_solo.submit(Request(rid=0, prompt=p, max_new_tokens=5))
        eng_solo.run_until_drained()
        solo_outputs.append(eng_solo.done[0].output)

    for i in range(3):
        assert eng_multi.done[i].output == solo_outputs[i], i


def test_queue_depth_is_demand_signal(rng):
    cfg, params, eng = _engine(slots=1)
    for i in range(4):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, 50, 4).astype(np.int32),
                           max_new_tokens=2))
    d0 = eng.queue_depth()
    eng.step()
    assert eng.queue_depth() < d0  # admission consumed from the queue
