"""Checkpoint manager: roundtrip, atomic commit, GC, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(rng):
    return {
        "a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "b": {"w": jnp.asarray(rng.standard_normal((3,)), jnp.bfloat16),
              "n": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_mode=False)
    tree = _tree(rng)
    mgr.save(5, tree)
    assert mgr.latest_step() == 5
    out = mgr.restore(5, jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_save_then_restore(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_mode=True)
    tree = _tree(rng)
    mgr.save(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_uncommitted_checkpoint_invisible(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_mode=False)
    tree = _tree(rng)
    mgr.save(1, tree)
    # fake a torn write: step dir without DONE marker
    os.makedirs(tmp_path / "step_00000002")
    assert mgr.latest_step() == 1


def test_gc_keeps_newest(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_mode=False, keep=2)
    tree = _tree(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_shape_mismatch_rejected(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_mode=False)
    mgr.save(1, _tree(rng))
    bad_target = {
        "a": jax.ShapeDtypeStruct((5, 8), jnp.float32),
        "b": {"w": jax.ShapeDtypeStruct((3,), jnp.bfloat16),
              "n": jax.ShapeDtypeStruct((), jnp.int32)},
    }
    with pytest.raises(ValueError):
        mgr.restore(1, bad_target)


def test_restore_with_shardings(tmp_path, rng):
    """Elastic restore: leaves land with the requested sharding (1-device
    mesh here; the multi-device path is exercised in test_multidevice)."""
    mgr = CheckpointManager(str(tmp_path), async_mode=False)
    tree = _tree(rng)
    mgr.save(1, tree)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = jax.tree_util.tree_map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), tree)
    out = mgr.restore(1, jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree), sh)
    assert out["a"].sharding.mesh.shape == {"data": 1}
