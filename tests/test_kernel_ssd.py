"""SSD Pallas kernel + chunked-jnp path vs the sequential-scan oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ops import ssd_chunked_jnp, ssd_decode_step
from repro.kernels.ssd.ref import ssd_reference

CASES = [
    # B, S, H, P, G, N, chunk, init
    (2, 512, 4, 64, 1, 128, 256, False),
    (1, 300, 8, 32, 2, 64, 128, True),
    (2, 64, 2, 64, 1, 32, 256, False),    # S < chunk
    (1, 128, 4, 16, 4, 16, 32, True),     # many groups
]


def _mk(rng, B, S, H, P, G, N, dtype, init):
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), dtype)
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, H))) * 0.3 + 0.01,
                     jnp.float32)
    A = -jnp.asarray(np.abs(rng.standard_normal(H)) + 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, G, N)) * 0.3, dtype)
    Cm = jnp.asarray(rng.standard_normal((B, S, G, N)) * 0.3, dtype)
    D = jnp.asarray(rng.standard_normal(H), jnp.float32)
    st = (jnp.asarray(np.abs(rng.standard_normal((B, H, P, N))) * 0.1,
                      jnp.float32) if init else None)
    return x, dt, A, Bm, Cm, D, st


@pytest.mark.parametrize("B,S,H,P,G,N,chunk,init", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_oracle(rng, B, S, H, P, G, N, chunk, init, dtype):
    x, dt, A, Bm, Cm, D, st = _mk(rng, B, S, H, P, G, N, dtype, init)
    y, fin = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk,
                        initial_state=st, interpret=True)
    yr, finr = ssd_reference(x, dt, A, Bm, Cm, D, initial_state=st)
    tol = 2e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finr),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,P,G,N,chunk,init", CASES[:2])
def test_chunked_jnp_matches_oracle(rng, B, S, H, P, G, N, chunk, init):
    x, dt, A, Bm, Cm, D, st = _mk(rng, B, S, H, P, G, N, jnp.float32, init)
    y, fin = ssd_chunked_jnp(x, dt, A, Bm, Cm, D, chunk=chunk,
                             initial_state=st)
    yr, finr = ssd_reference(x, dt, A, Bm, Cm, D, initial_state=st)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finr),
                               atol=2e-3, rtol=2e-3)


def test_decode_steps_match_full_sequence(rng):
    """Running ssd_decode_step token-by-token must reproduce the full-seq
    scan — the prefill->decode handoff invariant."""
    B, S, H, P, G, N = 1, 48, 2, 16, 1, 32
    x, dt, A, Bm, Cm, D, _ = _mk(rng, B, S, H, P, G, N, jnp.float32, False)
    y_full, state_full = ssd_reference(x, dt, A, Bm, Cm, D)
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        state, y_t = ssd_decode_step(
            state, x[:, t].reshape(B, H, P), dt[:, t], A, Bm[:, t],
            Cm[:, t], D)
        ys.append(y_t)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_full),
                               atol=1e-4, rtol=1e-4)


def test_state_passthrough_on_padding(rng):
    """dt=0 steps must not change the state (padding invariant the
    wrapper relies on)."""
    B, S, H, P, G, N = 1, 32, 2, 16, 1, 16
    x, dt, A, Bm, Cm, D, st = _mk(rng, B, S, H, P, G, N, jnp.float32, True)
    dt0 = jnp.zeros_like(dt)
    _, fin = ssd_chunked_jnp(x, dt0, A, Bm, Cm, D, chunk=16,
                             initial_state=st)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(st), atol=1e-6)
