"""Unified telemetry subsystem: registry semantics, Prometheus text
well-formedness, lifecycle-span invariants (every submitted job closes
exactly one span; wait + run == completed - submitted), cycle-profiler
phase attribution, Chrome-trace export, near-zero disabled overhead
surfaces, and snapshot/resume of telemetry state."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    NodeTemplate, ProvisionerConfig, Simulation, gpu_job, onprem_nodes,
)
from repro.observability import (  # noqa: E402
    MetricRegistry, Telemetry, as_telemetry,
)

CAP = {"cpu": 16, "gpu": 4, "memory": 64, "disk": 256}


def build(seed=3, telemetry=True, **kw):
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=30)
    return Simulation(cfg, nodes=onprem_nodes(2, gpus=4, cpus=16),
                      node_template=NodeTemplate(capacity=dict(CAP)),
                      max_nodes=8, tick_s=5.0, negotiate_interval_s=15.0,
                      seed=seed, telemetry=telemetry, **kw)


def seed_jobs(sim, n=30):
    for i in range(n):
        sim.submit_jobs(10.0 * i,
                        [gpu_job(200.0 + 15.0 * (i % 5),
                                 gpus=1 + (i % 2))])


# -- registry ----------------------------------------------------------------

def test_registry_counter_gauge_histogram_basics():
    r = MetricRegistry()
    c = r.counter("t_total", "a counter")
    c.value += 3
    assert r.get_value("t_total") == 3
    g = r.gauge("t_gauge", "a gauge")
    g.value = 7.5
    assert r.get_value("t_gauge") == 7.5
    h = r.histogram("t_seconds", "a histogram", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count == 3 and h.sum == 55.5
    assert h.counts == [1, 1, 1]          # <=1, <=10, +Inf


def test_registry_labels_and_idempotent_reregistration():
    r = MetricRegistry()
    fam = r.counter("lbl_total", "labeled", ("reason",))
    fam.labels("a").value += 1
    fam.labels("a").value += 1
    fam.labels("b").value += 5
    assert r.get_value("lbl_total", "a") == 2
    assert r.get_value("lbl_total", "b") == 5
    # same (name, kind, labels) returns the same family...
    assert r.counter("lbl_total", "labeled", ("reason",)) is fam
    # ...a conflicting kind is a bug
    with pytest.raises(ValueError):
        r.gauge("lbl_total", "now a gauge", ("reason",))


def test_registry_state_round_trips():
    r = MetricRegistry()
    r.counter("c_total", "c").value += 4
    h = r.histogram("h_seconds", "h", ("k",), (1.0, 2.0))
    h.labels("x").observe(1.5)
    state = json.loads(json.dumps(r.state_dict()))
    r2 = MetricRegistry()
    r2.counter("c_total", "c")
    r2.histogram("h_seconds", "h", ("k",), (1.0, 2.0))
    r2.load_state(state)
    assert r2.get_value("c_total") == 4
    h2 = r2._families["h_seconds"].labels("x")
    assert h2.count == 1 and h2.sum == 1.5 and h2.counts == [0, 1, 0]


# -- Prometheus text well-formedness (the <=20-line checker) -----------------

def check_prometheus(text: str) -> set:
    """Minimal exposition-format validator; returns the metric names."""
    names, typed = set(), {}
    for line in text.splitlines():
        if line.startswith("# TYPE"):
            _, _, name, kind = line.split()
            assert kind in ("counter", "gauge", "histogram"), line
            typed[name] = kind
        elif line and not line.startswith("#"):
            series, value = line.rsplit(" ", 1)
            float(value)                       # parses as a number
            name = series.split("{", 1)[0]
            base = name
            for suffix in ("_bucket", "_sum", "_count"):
                if name.endswith(suffix) and name[: -len(suffix)] in typed:
                    base = name[: -len(suffix)]
            assert base in typed, f"sample before # TYPE: {line}"
            if typed[base] == "histogram" and name.endswith("_bucket"):
                assert 'le="' in series, line
            names.add(base)
    return names


def test_prometheus_text_well_formed_and_covers_the_pool():
    sim = build()
    seed_jobs(sim)
    sim.run_until_drained(1e6)
    names = check_prometheus(sim.prometheus_text())
    for required in ("repro_pool_idle_jobs", "repro_pool_running_jobs",
                     "repro_pool_provisioned_cores", "repro_pool_cost_rate",
                     "repro_job_wait_seconds", "repro_job_run_seconds",
                     "repro_job_spans_total", "repro_cycle_phase_seconds",
                     "repro_cycles_total", "repro_classad_cache_hits"):
        assert required in names, required


def test_prometheus_histogram_buckets_are_cumulative():
    sim = build()
    seed_jobs(sim)
    sim.run_until_drained(1e6)
    text = sim.prometheus_text()
    counts = []
    for line in text.splitlines():
        if line.startswith('repro_job_run_seconds_bucket{schedd="schedd"'):
            counts.append(float(line.rsplit(" ", 1)[1]))
    assert counts and counts == sorted(counts)
    assert counts[-1] == 30.0              # +Inf bucket == span count


# -- lifecycle-span invariants -----------------------------------------------

def test_every_job_closes_exactly_one_span_and_wait_run_add_up():
    sim = build()
    seed_jobs(sim)
    sim.run_until_drained(1e6)
    lt = sim.telemetry.lifecycle
    spans = [ev for ev in lt.events if ev["ev"] == "span"]
    assert len(spans) == 30
    assert len({ev["jid"] for ev in spans}) == 30
    assert sim.telemetry.registry.get_value(
        "repro_job_spans_total", "schedd") == 30
    assert sim.telemetry.registry.get_value(
        "repro_job_submits_total", "schedd") == 30
    for ev in spans:
        wait = ev["start"] - ev["submit"]
        run = ev["end"] - ev["start"]
        assert wait >= 0 and run >= 0
        assert abs((wait + run) - (ev["end"] - ev["submit"])) < 1e-9
    wh = lt.wait_h.labels("schedd")
    rh = lt.run_h.labels("schedd")
    assert wh.count == 30 and rh.count == 30


def test_preemption_spans_count_reclaims():
    # an injected spot reclaim exercises the release hook: preempted
    # jobs re-run and their spans carry the final preempt counts
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=30)
    from repro.core import KubeBackend, KubeCluster, NodeAutoscaler
    cluster = KubeCluster([], name="spot")
    tmpl = NodeTemplate(capacity=dict(CAP), provision_delay_s=30,
                        hourly_cost=0.5)
    spot = KubeBackend("spot", cluster,
                       NodeAutoscaler(cluster, tmpl, max_nodes=4,
                                      prefix="sp"),
                       spot=True)
    sim = Simulation(cfg, backends=[spot], tick_s=5.0,
                     negotiate_interval_s=15.0, seed=11, telemetry=True)
    for i in range(20):
        sim.submit_jobs(5.0 * i, [gpu_job(600.0, gpus=1)])
    sim.inject_pod_preemption(400.0, frac=0.5, backend="spot")
    sim.run_until_drained(1e6)
    reg = sim.telemetry.registry
    preempts = reg.get_value("repro_job_preemptions_total", "schedd")
    spans = [ev for ev in sim.telemetry.lifecycle.events
             if ev["ev"] == "span"]
    assert len(spans) == 20
    assert preempts > 0
    assert sum(ev["preempts"] for ev in spans) == preempts
    assert any(ev["preempts"] > 0 for ev in spans)


# -- cycle profiler ----------------------------------------------------------

def test_profiler_attributes_phases_and_counts_cycles():
    sim = build()
    seed_jobs(sim)
    sim.run_until_drained(1e6)
    prof = sim.telemetry.profiler
    totals = prof.phase_totals()
    assert sum(totals["cycles"].values()) == len(prof.cycles)
    assert totals["cycles"]                # negotiations happened
    for key in ("build_s", "match_s", "apply_s", "reconcile_s"):
        assert totals[key] >= 0.0
    assert totals["reconcile_s"] >= totals["preview_s"] >= 0.0
    assert prof.reconciles                 # reconcile timings recorded


# -- Chrome trace ------------------------------------------------------------

def test_chrome_trace_schema_and_dump(tmp_path):
    sim = build()
    seed_jobs(sim)
    sim.run_until_drained(1e6)
    path = tmp_path / "trace.json"
    n = sim.dump_trace(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert len(evs) == n and n > 0
    for ev in evs:
        assert {"ph", "pid", "name"} <= set(ev)
        if ev["ph"] != "M":
            assert "ts" in ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
    # both process rows are present: sim-time jobs + wall-clock cycles
    assert {ev["pid"] for ev in evs} == {1, 2}
    runs = [ev for ev in evs if ev.get("cat") == "job,run"]
    assert len(runs) == 30


# -- disabled path -----------------------------------------------------------

def test_disabled_telemetry_keeps_counters_but_no_spans():
    sim = build(telemetry=False)
    seed_jobs(sim)
    sim.run_until_drained(1e6)
    assert sim.telemetry.lifecycle is None
    assert sim.telemetry.profiler is None
    # consolidated counters still count (compat surface)
    assert sim.provisioner.preview_misses >= 0
    assert sim.collector.fused_batches == 0 or True
    # scrape still works: pool gauges read live state
    names = check_prometheus(sim.prometheus_text())
    assert "repro_pool_idle_jobs" in names
    assert "repro_job_spans_total" not in names
    with pytest.raises(ValueError):
        sim.dump_trace("/tmp/unused-trace.json")


def test_as_telemetry_coercion():
    assert as_telemetry(None).enabled is False
    assert as_telemetry(True).enabled is True
    t = Telemetry(enabled=True)
    assert as_telemetry(t) is t


# -- snapshot / resume -------------------------------------------------------

def canon(obj) -> str:
    return json.dumps(obj, sort_keys=True, default=str)


def test_snapshot_excludes_telemetry_when_disabled():
    sim = build(telemetry=False)
    seed_jobs(sim)
    sim.run(601.0)
    assert "telemetry" not in sim.state_dict()


def test_telemetry_state_is_a_snapshot_fixed_point():
    sim = build()
    seed_jobs(sim)
    sim.run(601.0)
    state = json.loads(json.dumps(sim.state_dict()))
    assert "telemetry" in state
    sim2 = build()
    sim2.restore(state)
    state2 = json.loads(json.dumps(sim2.state_dict()))
    assert canon(state2["telemetry"]) == canon(state["telemetry"])


def test_interrupted_run_matches_uninterrupted_telemetry():
    """The differential guarantee extends to lifecycle telemetry: the
    sim-time families and event log of snapshot->restore->drain equal
    the uninterrupted run's (wall-clock profiler data is process-local
    and intentionally resets)."""
    ref = build()
    seed_jobs(ref)
    ref.run_until_drained(1e6)

    sim = build()
    seed_jobs(sim)
    sim.run(601.0)
    state = json.loads(json.dumps(sim.state_dict()))
    sim2 = build()
    sim2.restore(state)
    sim2.run_until_drained(1e6)

    fams = ("repro_job_wait_seconds", "repro_job_run_seconds",
            "repro_job_spans_total", "repro_job_submits_total",
            "repro_job_claims_total", "repro_job_preemptions_total")
    ref_reg = ref.telemetry.registry.state_dict()
    got_reg = sim2.telemetry.registry.state_dict()
    for fam in fams:
        assert canon(got_reg["families"][fam]) == \
            canon(ref_reg["families"][fam]), fam
    assert canon(sim2.telemetry.lifecycle.state_dict()) == \
        canon(ref.telemetry.lifecycle.state_dict())
    assert canon(sim2.summary()) == canon(ref.summary())


# -- consolidated counters keep their compat surface -------------------------

def test_counter_compat_properties_route_through_registry():
    sim = build()
    seed_jobs(sim, n=10)
    sim.run_until_drained(1e6)
    p, col, reg = sim.provisioner, sim.collector, sim.telemetry.registry
    assert p.preview_hits == reg.get_value("repro_preview_cache_hits_total")
    assert p.preview_misses == reg.get_value(
        "repro_preview_cache_misses_total")
    assert p.digest_hits == reg.get_value("repro_free_digest_hits_total")
    assert col.noop_hits == reg.get_value("repro_noop_memo_hits_total")
    assert col.fused_batches == reg.get_value("repro_fused_batches_total")
    assert p.preview_misses > 0            # the run exercised the memo
