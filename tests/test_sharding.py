"""Sharding-rule unit tests (1-device mesh; multi-device in subprocess)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.models import model as model_lib
from repro.models.param import Param, axes_tree, is_param
from repro.parallel.sharding import (
    logical_to_spec, param_sharding_tree, rules_for, spec_for,
)


class FakeMesh:
    """Shape-only stand-in so we can test 16×16 rules without devices."""

    def __init__(self, shape):
        self.shape = shape
        self.empty = False


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_basic_rules():
    # non-MoE training default is the §Perf-winning zero3 preset
    r = rules_for(get_config("qwen2-1.5b"), "train")
    assert r.name == "zero3"
    assert logical_to_spec(("embed", "mlp"), r, MESH) == P("data", "model")
    assert logical_to_spec(("batch", "seq"), r, MESH) == \
        P(("data", "model"), None)
    # the paper-era TP baseline stays available as a preset
    from repro.parallel.sharding import preset
    assert logical_to_spec(("embed", "mlp"), preset("base"), MESH) == \
        P(None, "model")


def test_moe_rules_expert_axis():
    r = rules_for(get_config("llama4-scout-17b-a16e"), "train")
    spec = logical_to_spec(("expert", "embed", "mlp"), r, MESH)
    assert spec == P("data", None, "model")  # embed dropped: data taken


def test_duplicate_mesh_axis_dropped():
    r = rules_for(get_config("granite-8b"), "train")
    # embed->data twice: second occurrence must fall back to None
    spec = logical_to_spec(("embed", "embed"), r, MESH)
    assert spec == P("data", None)


def test_batch_axes_multi_pod():
    r = rules_for(get_config("llama4-scout-17b-a16e"), "train")  # ep preset
    spec = logical_to_spec(("batch", "seq", "embed"), r, MESH3)
    assert spec[0] == ("pod", "data")


def test_spec_for_divisibility_guard():
    r = rules_for(get_config("mamba2-1.3b"), "train")
    # vocab 50280 % 16 != 0 -> vocab axis dropped
    spec = spec_for((50280, 2048), ("vocab", "embed"), r, MESH)
    assert spec[0] is None
    spec2 = spec_for((51200, 2048), ("vocab", "embed"), r, MESH)
    assert spec2[0] == "model"


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("workload", ["train", "decode"])
def test_all_param_specs_divisible(arch, workload):
    """Property over the whole zoo: every generated param spec must be
    loadable (dims divisible by their mesh-axis product)."""
    cfg = get_config(arch)
    rules = rules_for(cfg, workload)
    tree = model_lib.init_model(cfg)
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_param)
    for p in leaves:
        spec = spec_for(p.shape, p.axes, rules, MESH)
        for dim, ax in zip(p.shape, spec):
            if ax is None:
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= MESH.shape[a]
            assert dim % size == 0, (arch, p.shape, p.axes, spec)
