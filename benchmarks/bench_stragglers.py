"""Straggler mitigation (beyond-paper): makespan with/without speculative
rescheduling when a fraction of nodes silently degrade to 10–30% speed —
the dominant failure mode at 1000+-node scale (thermal throttling, bad
HBM, noisy neighbours) that HTCondor-style job-level rescheduling absorbs.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import ProvisionerConfig, Simulation, gpu_job, onprem_nodes
from repro.core.stragglers import StragglerPolicy


def _run(policy, *, frac: float, rate: float, seed: int = 0):
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=10)
    sim = Simulation(cfg, nodes=onprem_nodes(4, gpus=8), tick_s=5,
                     seed=seed, straggler_policy=policy)
    sim.submit_jobs(0, [gpu_job(600, gpus=1, checkpoint_interval_s=120)
                        for _ in range(24)])
    sim.inject_slow_workers(120, frac=frac, rate=rate)
    sim.run_until_drained(max_t=60000)
    s = sim.summary()
    return {
        "makespan_s": sim.now,
        "completed": s["jobs"]["n"],
        "rescheduled": policy.rescheduled if policy else 0,
        "workers_retired": policy.retired_workers if policy else 0,
        "goodput": s["jobs"].get("goodput", 1.0),
    }


def run(echo: bool = True) -> dict:
    out = {}
    for frac, rate in ((0.3, 0.1), (0.5, 0.3)):
        base = _run(None, frac=frac, rate=rate)
        mit = _run(StragglerPolicy(factor=1.5), frac=frac, rate=rate)
        out[f"slow{int(frac*100)}pct_rate{rate}"] = {
            "no_mitigation": base,
            "with_mitigation": mit,
            "makespan_speedup": base["makespan_s"] / mit["makespan_s"],
        }
        assert mit["completed"] == base["completed"] == 24
        assert mit["makespan_s"] <= base["makespan_s"]
    emit("stragglers", out, echo=echo)
    return out


if __name__ == "__main__":
    run()
