"""C2: self-termination scale-down — idle-timeout sweep.

Scale-down in the paper is emergent (workers exit when no matching work
waits).  The idle_timeout trades wasted idle resource-seconds against
re-provisioning latency for the next burst.  We measure both sides.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import ProvisionerConfig, Simulation, gpu_job, onprem_nodes


def _run(idle_timeout: float, second_wave_gap: float, seed: int = 0):
    cfg = ProvisionerConfig(submit_interval_s=30,
                            idle_timeout_s=idle_timeout,
                            startup_delay_s=60)
    sim = Simulation(cfg, nodes=onprem_nodes(4, gpus=8), tick_s=5,
                     seed=seed)
    sim.submit_jobs(0, [gpu_job(600, gpus=1) for _ in range(16)])
    sim.submit_jobs(second_wave_gap,
                    [gpu_job(600, gpus=1) for _ in range(16)])
    sim.run_until_drained(max_t=40000)
    s = sim.summary()
    idle_s = s["workers"]["alive_s"] - s["workers"]["busy_s"]
    return {
        "idle_timeout_s": idle_timeout,
        "pods_submitted": s["pods_submitted"],
        "worker_idle_s": idle_s,
        "worker_utilization": s["workers"]["utilization"],
        "second_wave_wait_s": s["jobs"]["mean_wait_s"],
        "makespan_s": sim.now,
    }


def run(echo: bool = True) -> dict:
    gap = 1500  # second burst lands after the first drains
    rows = [_run(t, gap) for t in (60, 300, 900)]
    out = {f"timeout_{int(r['idle_timeout_s'])}s": r for r in rows}
    # short timeout -> fewer idle seconds; long timeout -> fewer new pods
    assert rows[0]["worker_idle_s"] <= rows[-1]["worker_idle_s"]
    assert rows[-1]["pods_submitted"] <= rows[0]["pods_submitted"]
    emit("scaledown", out, echo=echo)
    return out


if __name__ == "__main__":
    run()
