"""Event engine vs. the seed tick loop: matchmaking throughput at scale.

The tentpole claim: a heap-scheduled event loop + indexed job queue +
cohort-vectorized negotiator turns the O(jobs×workers)-per-tick seed
harness into one that drains 100k-job federated campaigns in seconds.

Two modes:

  * default (10k jobs): runs BOTH engines on the same 3-backend
    federation and reports the jobs/sec ratio (acceptance: >= 10x)
  * CI smoke (--jobs 1000 --budget-s N): wall-clock budget on the event
    engine so matchmaking-throughput regressions fail the build; the
    baseline ratio is still recorded

Usage:
    python benchmarks/bench_event_engine.py [--jobs 10000]
        [--budget-s SECONDS] [--no-baseline] [--min-ratio 10]
    python benchmarks/bench_event_engine.py --jobs 100000 --no-baseline
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import Timer, emit
from repro.core import (
    KubeBackend, KubeCluster, NodeAutoscaler, NodeTemplate,
    ProvisionerConfig, Simulation, gpu_job, onprem_nodes,
)


def federation():
    """3 providers: static on-prem, NAP-style cloud, spot (paper §2+§6)."""
    onprem = KubeBackend(
        "onprem", KubeCluster(onprem_nodes(8, gpus=8, prefix="onprem"),
                              name="onprem"))
    backends = [onprem]
    for name, max_nodes, hourly, spot in (
        ("cloud", 24, 2.5, False), ("spot", 24, 0.8, True),
    ):
        cluster = KubeCluster([], name=name)
        tmpl = NodeTemplate(
            capacity={"cpu": 64, "gpu": 8, "memory": 512, "disk": 1024},
            provision_delay_s=60, scale_down_delay_s=300,
            hourly_cost=hourly)
        backends.append(KubeBackend(
            name, cluster,
            NodeAutoscaler(cluster, tmpl, max_nodes=max_nodes,
                           prefix=f"{name}-np"),
            spot=spot))
    return backends


def build(n_jobs: int, engine: str, *, telemetry: bool = False) -> Simulation:
    cfg = ProvisionerConfig(
        submit_interval_s=30, idle_timeout_s=120, startup_delay_s=30,
        max_pods_per_group=600, max_total_pods=600)
    sim = Simulation(cfg, backends=federation(), tick_s=5, engine=engine,
                     metrics_interval_s=60 if engine == "event" else None,
                     telemetry=telemetry)
    sim.submit_jobs(0, [gpu_job(120, gpus=1) for _ in range(n_jobs)])
    return sim


def drain(n_jobs: int, engine: str, *, telemetry: bool = False) -> dict:
    sim = build(n_jobs, engine, telemetry=telemetry)
    with Timer() as t:
        sim.run_until_drained(max_t=5e6)
    assert sim.queue.drained(), f"{engine} engine failed to drain"
    done = len(sim.queue.completed_log)
    assert done == n_jobs, (done, n_jobs)
    row = {
        "engine": engine,
        "jobs": n_jobs,
        "wall_s": round(t.s, 3),
        "jobs_per_sec": round(done / t.s, 1),
        "makespan_s": sim.now,
        "pods_submitted": sim.provisioner.stats.submitted,
        "gpu_utilization": round(sim.summary()["gpu_utilization"], 4),
    }
    prof = sim.collector.profiler
    if prof is not None:
        totals = prof.phase_totals()
        row["phases"] = {k: (round(v, 4) if isinstance(v, float) else v)
                         for k, v in totals.items()}
    return row


def preview_split_meta(row: dict) -> dict:
    """The reconcile/preview wall split of one telemetry-on drain, in
    the shape stamped into the artifact's ``_meta`` block."""
    phases = row.get("phases") or {}
    return {"reconcile_preview_split": {
        "jobs": row["jobs"],
        "reconcile_s": phases.get("reconcile_s"),
        "preview_s": phases.get("preview_s"),
        "jit_compiles_by_path": phases.get("jit_compiles_by_path"),
    }}


def run(echo: bool = True) -> dict:
    """Unified-runner entry (benchmarks.run): 1k-job event-vs-tick
    comparison, same shape the CI smoke uses."""
    event = drain(1_000, "event")
    tick = drain(1_000, "tick")
    probe = drain(1_000, "event", telemetry=True)
    ratio = event["jobs_per_sec"] / max(tick["jobs_per_sec"], 1e-9)
    payload = {"event": event, "tick": tick, "speedup": round(ratio, 2)}
    assert ratio >= 5, f"event engine speedup collapsed: {ratio:.1f}x"
    emit("event_engine", payload, echo=echo, meta=preview_split_meta(probe))
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=10_000)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if the event engine's wall time exceeds this")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the (slow) tick-loop baseline")
    ap.add_argument("--min-ratio", type=float, default=None,
                    help="fail if event/tick jobs-per-sec ratio is below")
    ap.add_argument("--max-overhead", type=float, default=None,
                    metavar="R",
                    help="telemetry overhead guard: fail if the best "
                         "telemetry-ON drain exceeds R x the best "
                         "telemetry-OFF drain (3 runs each); the "
                         "disabled path does strictly less work, so "
                         "this bounds its overhead a fortiori")
    args = ap.parse_args(argv)

    event = drain(args.jobs, "event")
    payload: dict = {"event": event}
    print(f"event engine: {event['jobs_per_sec']} jobs/s "
          f"({event['wall_s']}s wall, makespan {event['makespan_s']:.0f}s)")

    if not args.no_baseline:
        tick = drain(args.jobs, "tick")
        ratio = event["jobs_per_sec"] / max(tick["jobs_per_sec"], 1e-9)
        payload["tick"] = tick
        payload["speedup"] = round(ratio, 2)
        print(f"tick baseline: {tick['jobs_per_sec']} jobs/s "
              f"({tick['wall_s']}s wall) -> speedup {ratio:.1f}x")
        if args.min_ratio is not None and ratio < args.min_ratio:
            print(f"FAIL: speedup {ratio:.1f}x < required "
                  f"{args.min_ratio}x", file=sys.stderr)
            return 1

    probe = None
    if args.max_overhead is not None:
        # interleave the two modes so drift (thermal, page cache, jit
        # warmup) hits both equally; best-of-N filters the noise floor
        walls_off, walls_on = [event["wall_s"]], []
        for _ in range(4):
            row = drain(args.jobs, "event", telemetry=True)
            if probe is None:
                probe = row
            walls_on.append(row["wall_s"])
            walls_off.append(drain(args.jobs, "event")["wall_s"])
        ratio = min(walls_on) / max(min(walls_off), 1e-9)
        payload["overhead"] = {
            "telemetry_off_s": min(walls_off),
            "telemetry_on_s": min(walls_on),
            "ratio": round(ratio, 4), "max": args.max_overhead}
        print(f"telemetry overhead: off {min(walls_off)}s / "
              f"on {min(walls_on)}s -> ratio {ratio:.3f} "
              f"(max {args.max_overhead})")
        if ratio > args.max_overhead:
            print(f"FAIL: telemetry overhead {ratio:.3f} > "
                  f"{args.max_overhead}", file=sys.stderr)
            emit("event_engine", payload, meta=preview_split_meta(probe))
            return 1

    if probe is None:
        # cheap instrumented drain just for the _meta phase split
        probe = drain(min(args.jobs, 2_000), "event", telemetry=True)
    emit("event_engine", payload, meta=preview_split_meta(probe))
    if args.budget_s is not None and event["wall_s"] > args.budget_s:
        print(f"FAIL: event engine took {event['wall_s']}s "
              f"> budget {args.budget_s}s", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
