"""Streaming trace replay at scale: throughput + bounded memory.

Generates an OSG-shaped diurnal trace (workload/generators.py), streams
it through the standard 3-backend federation with the event engine, and
reports replay throughput plus the live-`Job` ceiling — the claim that a
100k-arrival campaign is fed incrementally (jobs exist from arrival to
completion only), not materialized upfront.

Two modes:

  * default (10k jobs): full diurnal day, cheapest-first policy; records
    jobs/sec, peak live jobs, conservation of core-hours
  * CI smoke (--jobs 2000 --budget-s N): wall-clock budget so replay
    regressions fail the build

Usage:
    python benchmarks/bench_trace_replay.py [--jobs 10000]
        [--budget-s SECONDS] [--coalesce-s 10] [--max-live N]
"""
from __future__ import annotations

import argparse
import sys
import weakref

from benchmarks.common import Timer, emit
from repro.workload.compare import standard_policy
from repro.workload.generators import diurnal_day
from repro.workload.replay import replay_trace


def replay_run(n_jobs: int, *, coalesce_s: float = 10.0,
               duration_s: float = 86400.0, seed: int = 7) -> dict:
    trace = diurnal_day(n_jobs, seed=seed, duration_s=duration_s)
    spec = standard_policy("cheapest-first")
    sim = spec.build()

    state = {"live": 0, "peak": 0}

    def factory(rec):
        job = rec.to_job()
        state["live"] += 1
        state["peak"] = max(state["peak"], state["live"])
        weakref.finalize(
            job, lambda: state.__setitem__("live", state["live"] - 1))
        return job

    rep = replay_trace(sim, iter(trace.records), coalesce_s=coalesce_s,
                       compact_completed=True, job_factory=factory)
    with Timer() as t:
        sim.run_until_drained(max_t=5e6)
    assert sim.queue.drained(), "replay failed to drain"
    done = rep.stats.completed
    assert done.n == n_jobs, (done.n, n_jobs)
    expect_core_s = trace.total_core_seconds()
    assert abs(done.core_seconds - expect_core_s) <= 1e-6 * expect_core_s, \
        "core-hour conservation violated"
    return {
        "jobs": n_jobs,
        "wall_s": round(t.s, 3),
        "jobs_per_sec": round(n_jobs / t.s, 1),
        "makespan_s": round(sim.now, 1),
        "peak_live_jobs": state["peak"],
        "replay_batches": rep.stats.batches,
        "coalesce_s": coalesce_s,
        "p95_wait_s": round(done.summary()["p95_wait_s"], 1),
        "core_hours": round(done.core_seconds / 3600.0, 2),
        "cost_total": round(sim.summary()["cost_total"], 2),
    }


def run(echo: bool = True) -> dict:
    """Unified-runner entry (benchmarks.run): small fixed-size replay."""
    payload = replay_run(2000, duration_s=14400.0)
    assert payload["peak_live_jobs"] < 2000, \
        "streaming replay materialized the whole campaign"
    emit("trace_replay", payload, echo=echo)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=10_000)
    ap.add_argument("--duration-s", type=float, default=86400.0)
    ap.add_argument("--coalesce-s", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if replay wall time exceeds this")
    ap.add_argument("--max-live", type=int, default=None,
                    help="fail if peak live jobs exceeds this")
    args = ap.parse_args(argv)

    payload = replay_run(args.jobs, coalesce_s=args.coalesce_s,
                         duration_s=args.duration_s, seed=args.seed)
    print(f"trace replay: {payload['jobs_per_sec']} jobs/s "
          f"({payload['wall_s']}s wall), peak live "
          f"{payload['peak_live_jobs']}/{args.jobs} jobs")
    emit("trace_replay", payload)
    if args.budget_s is not None and payload["wall_s"] > args.budget_s:
        print(f"FAIL: {payload['wall_s']}s > budget {args.budget_s}s",
              file=sys.stderr)
        return 1
    if args.max_live is not None and \
            payload["peak_live_jobs"] > args.max_live:
        print(f"FAIL: peak live {payload['peak_live_jobs']} > "
              f"{args.max_live}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
