"""Multi-schedd flocking at scale: negotiation-cycle overhead vs schedds.

Replays the SAME 10k-job OSG-shaped trace through the standard
3-backend federation three ways — 1, 4, and 16 schedds (split by user
so every schedd gets demand) with hierarchical fair-share on — and
compares against the single-queue baseline path on the identical trace.

The guard: the 1-schedd flocking path must stay within --max-ratio
(default 1.5x) of the single-queue wall time, i.e. the multi-queue
refactor is free when you don't use it; 4/16 schedds are reported so
cycle-cost growth with federation width is visible in CI history.

Usage:
    python benchmarks/bench_flocking.py [--jobs 10000]
        [--budget-s SECONDS] [--max-ratio 1.5] [--schedds 1 4 16]
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import Timer, emit
from repro.workload.compare import run_policy, standard_policy
from repro.workload.generators import diurnal_day


def flocking_run(n_jobs: int, *, schedd_counts=(1, 4, 16),
                 duration_s: float = 86400.0, seed: int = 7,
                 coalesce_s: float = 10.0) -> dict:
    trace = diurnal_day(n_jobs, seed=seed, duration_s=duration_s)
    spec = standard_policy("cheapest-first")

    def one(schedds: int | None) -> dict:
        with Timer() as t:
            if schedds is None:        # single-queue baseline path
                r = run_policy(trace, spec, coalesce_s=coalesce_s)
            else:
                r = run_policy(trace, spec, coalesce_s=coalesce_s,
                               schedds=schedds, split_by="user",
                               fairshare=True)
        assert r["jobs"]["n"] == n_jobs, (r["jobs"]["n"], n_jobs)
        return {
            "wall_s": round(t.s, 3),
            "jobs_per_sec": round(n_jobs / t.s, 1),
            "makespan_s": r["makespan_s"],
            "p95_wait_s": round(r["jobs"]["p95_wait_s"], 1),
            "pods_submitted": r["pods_submitted"],
        }

    baseline = one(None)
    cells = {f"schedds_{n}": one(n) for n in schedd_counts}
    ratio1 = (cells["schedds_1"]["wall_s"] / baseline["wall_s"]
              if "schedds_1" in cells and baseline["wall_s"] > 0
              else None)
    return {
        "jobs": n_jobs,
        "single_queue": baseline,
        **cells,
        "flocking_overhead_at_1_schedd":
            round(ratio1, 3) if ratio1 is not None else None,
    }


def run(echo: bool = True) -> dict:
    """Unified-runner entry (benchmarks.run): small fixed-size grid."""
    payload = flocking_run(2000, schedd_counts=(1, 4),
                           duration_s=14400.0)
    emit("flocking", payload, echo=echo)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=10_000)
    ap.add_argument("--duration-s", type=float, default=86400.0)
    ap.add_argument("--coalesce-s", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--schedds", type=int, nargs="*", default=[1, 4, 16])
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if TOTAL wall time exceeds this")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    help="fail if 1-schedd flocking wall time exceeds "
                         "this multiple of the single-queue path")
    args = ap.parse_args(argv)

    payload = flocking_run(args.jobs, schedd_counts=tuple(args.schedds),
                           duration_s=args.duration_s, seed=args.seed,
                           coalesce_s=args.coalesce_s)
    total = payload["single_queue"]["wall_s"] + sum(
        payload[f"schedds_{n}"]["wall_s"] for n in args.schedds)
    print(f"flocking: single-queue {payload['single_queue']['wall_s']}s; "
          + "; ".join(
              f"{n} schedds {payload[f'schedds_{n}']['wall_s']}s"
              for n in args.schedds)
          + f" (total {total:.1f}s)")
    emit("flocking", payload)
    ratio = payload["flocking_overhead_at_1_schedd"]
    if ratio is not None and ratio > args.max_ratio:
        print(f"FAIL: 1-schedd flocking is {ratio}x the single-queue "
              f"path (budget {args.max_ratio}x)", file=sys.stderr)
        return 1
    if args.budget_s is not None and total > args.budget_s:
        print(f"FAIL: {total:.1f}s > budget {args.budget_s}s",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
