"""Fig-3 analogue: demand-driven node auto-provisioning on a GKE-like
elastic cluster (7-GPU nodes, 1-GPU pods, spot semantics).

The paper's observations to reproduce:
  * provisioned node capacity tracks HTCondor-driven pod demand promptly
  * new nodes appear within the provisioning delay
  * deprovisioning leaves bounded waste ("close to the minimum
    achievable") because co-located pods rarely finish together

We drive a bursty demand pattern (3 waves), record the demand/supply time
series, and report tracking lag + waste fraction.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import (
    NodeTemplate, ProvisionerConfig, Simulation, gpu_job,
)


def run(seed: int = 0, echo: bool = True,
        scale_down_delay_s: float = 600.0) -> dict:
    cfg = ProvisionerConfig(
        submit_interval_s=30, idle_timeout_s=300, startup_delay_s=15,
        max_pods_per_group=200, max_total_pods=400,
    )
    tmpl = NodeTemplate(
        capacity={"cpu": 64, "gpu": 7, "memory": 512, "disk": 2048},
        provision_delay_s=90,      # instance boot + kubelet join
        scale_down_delay_s=scale_down_delay_s,  # GKE empty-node grace
    )
    sim = Simulation(cfg, nodes=[], node_template=tmpl, max_nodes=24,
                     tick_s=5, seed=seed)

    # three demand waves, as in the paper's test run
    sim.submit_jobs(0, [gpu_job(1800, gpus=1) for _ in range(30)])
    sim.submit_jobs(4000, [gpu_job(1200, gpus=1) for _ in range(70)])
    sim.submit_jobs(9000, [gpu_job(900, gpus=1) for _ in range(20)])
    sim.run(16000)
    sim.run_until_drained(max_t=40000)

    rec = sim.recorder
    lag = rec.tracking_lag("idle_jobs", "ready_workers", threshold=0.8)
    out = {
        "summary": sim.summary(),
        "tracking_lag_s_0.8": lag,
        "peak_nodes": rec.max("live_nodes"),
        "peak_demand": rec.max("idle_jobs"),
        "waste_fraction": sim.autoscaler.waste_fraction(),
        "nodes_provisioned": sim.autoscaler.provisioned_total,
        "nodes_deprovisioned": sim.autoscaler.deprovisioned_total,
        "series_tail": {
            k: rec.series[k][-3:] for k in ("idle_jobs", "live_nodes")
        },
    }
    # waste decomposition: most empty-node-seconds are the deliberate
    # scale-down grace, not bin-packing leftovers — re-run with a short
    # grace to separate the two (the paper's "minimum achievable")
    if scale_down_delay_s == 600.0:
        short = run(seed=seed, echo=False, scale_down_delay_s=120.0)
        out["waste_fraction_grace120"] = short["waste_fraction"]

    emit("tracking", out, echo=echo)
    # paper-facing checks
    assert out["nodes_provisioned"] == out["nodes_deprovisioned"]
    assert out["summary"]["jobs"]["n"] == 120
    return out


if __name__ == "__main__":
    run()
