"""Kernel micro-benchmarks: correctness-swept shapes + arithmetic-intensity
table for the three Pallas kernels (the wall-clock on CPU is the jnp
dispatch path; the table's flops/bytes are the TPU-kernel model used by
§Roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def _time(f, *args, n=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / n


def bench_attention():
    from repro.kernels.flash_attention.ops import flash_attention

    rows = []
    for (B, S, Hq, Hkv, Dh) in [(1, 1024, 8, 2, 128), (2, 2048, 8, 8, 64)]:
        q = jnp.ones((B, S, Hq, Dh), jnp.bfloat16)
        k = jnp.ones((B, S, Hkv, Dh), jnp.bfloat16)
        v = jnp.ones((B, S, Hkv, Dh), jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        dt = _time(lambda: flash_attention(q, k, v, pos, pos, causal=True))
        flops = 4 * B * Hq * S * S * Dh * 0.5         # causal half
        io = (2 * B * S * Hq * Dh + 2 * B * S * Hkv * Dh) * 2
        rows.append({
            "shape": f"B{B} S{S} H{Hq}/{Hkv} D{Dh}",
            "cpu_ms": dt * 1e3,
            "kernel_flops": flops,
            "kernel_hbm_bytes": io,
            "arith_intensity": flops / io,
        })
    return rows


def bench_ssd():
    from repro.kernels.ssd.ops import ssd

    rows = []
    for (B, S, H, P, N, Q) in [(1, 2048, 32, 64, 128, 256)]:
        x = jnp.ones((B, S, H, P), jnp.bfloat16)
        dt_ = jnp.full((B, S, H), 0.1, jnp.float32)
        A = -jnp.ones((H,), jnp.float32)
        Bm = jnp.ones((B, S, 1, N), jnp.bfloat16)
        Cm = jnp.ones((B, S, 1, N), jnp.bfloat16)
        D = jnp.ones((H,), jnp.float32)
        t = _time(lambda: ssd(x, dt_, A, Bm, Cm, D, chunk=Q))
        nc = S // Q
        flops = 2 * B * H * nc * (Q * Q * N + Q * Q * P + 2 * Q * P * N)
        io = (2 * B * S * H * P + 2 * B * S * N * 2) * 2
        rows.append({
            "shape": f"B{B} S{S} H{H} P{P} N{N} Q{Q}",
            "cpu_ms": t * 1e3,
            "kernel_flops": flops,
            "kernel_hbm_bytes": io,
            "arith_intensity": flops / io,
        })
    return rows


def bench_gmm():
    from repro.kernels.moe_gmm.ops import gmm

    rows = []
    for (E, T, K, N) in [(8, 4096, 1024, 4096)]:
        lhs = jnp.ones((T, K), jnp.bfloat16)
        rhs = jnp.ones((E, K, N), jnp.bfloat16)
        gs = jnp.full((E,), T // E, jnp.int32)
        t = _time(lambda: gmm(lhs, rhs, gs))
        flops = 2 * T * K * N
        io = (T * K + E * K * N + T * N) * 2
        rows.append({
            "shape": f"E{E} T{T} K{K} N{N}",
            "cpu_ms": t * 1e3,
            "kernel_flops": flops,
            "kernel_hbm_bytes": io,
            "arith_intensity": flops / io,
        })
    return rows


def run(echo: bool = True) -> dict:
    out = {
        "flash_attention": bench_attention(),
        "ssd": bench_ssd(),
        "moe_gmm": bench_gmm(),
        "note": ("cpu_ms is the jnp fallback path on this container; "
                 "kernel_flops/bytes are the Pallas-kernel roofline model "
                 "(v5e peak 197 TF bf16, 819 GB/s HBM => compute-bound "
                 "above intensity 240)"),
    }
    emit("kernels", out, echo=echo)
    return out


if __name__ == "__main__":
    run()
