"""Fig-2 analogue: opportunistic GPU harvest on a multi-tenant cluster.

The paper reports ~350k GPU-hours harvested from the PRP in 2021 at
`priority_class=opportunistic` with "no effect on other users".  We
reproduce the mechanism at simulation scale: a shared cluster runs a
high-priority service workload with diurnal load; the provisioner's batch
pods backfill the idle GPUs and get preempted whenever the services grow.

Reported: harvested GPU-hours, service-latency proxy (did every service
pod start immediately?), and batch goodput under preemption.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import (
    Pod, PodPhase, ProvisionerConfig, Simulation, gpu_job, onprem_nodes,
)


def run(seed: int = 0, days: float = 2.0, echo: bool = True) -> dict:
    cfg = ProvisionerConfig(
        submit_interval_s=60, idle_timeout_s=600, startup_delay_s=30,
        priority_class="opportunistic",
        max_pods_per_group=300, max_total_pods=600,
    )
    n_nodes, gpus = 8, 8
    sim = Simulation(cfg, nodes=onprem_nodes(n_nodes, gpus=gpus),
                     tick_s=30, seed=seed)
    horizon = days * 86400

    # high-priority "service" tenants with a diurnal pattern: occupy
    # 20%..70% of the cluster's GPUs, changing every 2 simulated hours
    rng = np.random.default_rng(seed)
    service_pods: list[str] = []

    def service_tick(sim: Simulation, now: float):
        frac = 0.45 + 0.25 * np.sin(2 * np.pi * now / 86400)
        want = int(frac * n_nodes * gpus)
        have = len([p for p in service_pods
                    if sim.cluster.pods.get(p) is not None
                    and sim.cluster.pods[p].phase == PodPhase.RUNNING])
        for i in range(have, want):
            pod = Pod(name=f"svc-{now:.0f}-{i}", request={"gpu": 1,
                      "cpu": 2, "memory": 8},
                      priority_class="production")
            sim.cluster.create_pod(pod, now)
            service_pods.append(pod.name)
        # shrink: delete newest service pods
        if want < have:
            running = [p for p in service_pods
                       if sim.cluster.pods.get(p) is not None]
            for name in running[want - have:]:
                sim.cluster.delete_pod(name, now, "completed")
                service_pods.remove(name)

    t = 0.0
    while t < horizon:
        sim.at(t, service_tick, name="service")
        t += 7200

    # a deep backlog of opportunistic 1-GPU batch jobs (OSG payloads);
    # they self-checkpoint every 10 min
    n_jobs = 800
    sim.submit_jobs(0, [gpu_job(3600, gpus=1, checkpoint_interval_s=600)
                        for _ in range(n_jobs)])
    sim.run(horizon)

    # service impact check: every service pod must have started the tick
    # it was created (never blocked by batch)
    svc_started = all(
        (p.started_at - p.created_at) <= 31
        for p in sim.cluster.pods.values() if p.name.startswith("svc")
        if p.started_at > 0
    )
    busy = sum(w.busy_s for w in sim.all_workers)
    s = sim.summary()
    out = {
        "harvested_gpu_hours": busy / 3600,
        "cluster_gpu_hours": n_nodes * gpus * sim.now / 3600,
        "harvest_fraction": busy / (n_nodes * gpus * sim.now),
        "jobs_completed": s["jobs"]["n"],
        "preemptions": s["jobs"].get("preemptions", 0),
        "goodput": s["jobs"].get("goodput", 1.0),
        "service_never_blocked": bool(svc_started),
        "worker_utilization": s["workers"]["utilization"],
    }
    emit("utilization", out, echo=echo)
    assert out["service_never_blocked"], "batch pods impacted services!"
    assert out["preemptions"] > 0, "preemption never exercised"
    return out


if __name__ == "__main__":
    run()
