"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import json
import os
import platform
import socket
import subprocess
import time


OUT_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "bench")

_META: dict | None = None
_BENCH_T0: float | None = None


def bench_meta() -> dict:
    """Host / toolchain / revision fingerprint, computed once per
    process — stamped into every emitted artifact so BENCH trajectories
    are comparable across machines and commits."""
    global _META
    if _META is None:
        sha = None
        try:
            p = subprocess.run(
                ["git", "rev-parse", "HEAD"],
                cwd=os.path.dirname(os.path.abspath(__file__)),
                capture_output=True, text=True, timeout=10)
            sha = p.stdout.strip() or None
        except (OSError, subprocess.SubprocessError):
            pass
        _META = {
            "host": socket.gethostname(),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "git_sha": sha,
        }
    return _META


def begin_bench() -> None:
    """Mark the start of one benchmark; the next emit() stamps the
    elapsed wall time into its ``_meta`` block."""
    global _BENCH_T0
    _BENCH_T0 = time.time()


def emit(name: str, payload: dict, *, echo: bool = True,
         meta: dict | None = None):
    """`meta` entries merge into the ``_meta`` block — bench-specific
    context (e.g. the reconcile/preview wall split) that should ride
    with the host fingerprint rather than the measurement payload."""
    doc_meta = dict(bench_meta())
    if _BENCH_T0 is not None:
        doc_meta["wall_s"] = round(time.time() - _BENCH_T0, 3)
    if meta:
        doc_meta.update(meta)
    doc = {**payload, "_meta": doc_meta}
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=str)
    if echo:
        print(f"== {name} ==")
        print(json.dumps(doc, indent=1, default=str))
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
