"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import json
import os
import time


OUT_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "bench")


def emit(name: str, payload: dict, *, echo: bool = True):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    if echo:
        print(f"== {name} ==")
        print(json.dumps(payload, indent=1, default=str))
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
