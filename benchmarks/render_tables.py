"""Render §Dry-run / §Roofline markdown tables from dry-run JSON dirs.

Usage:
  PYTHONPATH=src python -m benchmarks.render_tables \
      --dir experiments/dryrun_v2 --mesh single > /tmp/v2_table.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(dirname: str, mesh: str):
    rows = []
    for fn in sorted(glob.glob(os.path.join(dirname, f"*_{mesh}.json"))):
        rows.append(json.load(open(fn)))
    rows.sort(key=lambda c: (c["arch"], ORDER[c["cell"]]))
    return rows


def roofline_table(rows):
    out = ["| arch | cell | compute_s | memory_s | collective_s | bound | "
           "useful | roofline | peak GiB |", "|" + "---|" * 9]
    for c in rows:
        if c.get("skipped"):
            out.append(f"| {c['arch']} | {c['cell']} | — | — | — | — | — "
                       f"| skip | — |")
            continue
        if "error" in c:
            out.append(f"| {c['arch']} | {c['cell']} | ERROR: "
                       f"{c['error'][:60]} |")
            continue
        r = c.get("roofline_kernel_adjusted") or c["roofline"]
        peak = c.get("memory", {}).get("peak_memory_in_bytes", 0) / 2**30
        over = " ⚠" if peak > 16 else ""
        out.append(
            f"| {c['arch']} | {c['cell']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['bottleneck'].split('_')[0]} | "
            f"{r['useful_flop_ratio']:.1%} | "
            f"{r['roofline_fraction']:.2%} | {peak:.1f}{over} |")
    return "\n".join(out)


def compile_table(rows):
    """Compact compile-proof table (used for the multi-pod mesh)."""
    out = ["| arch | cell | compile_s | peak GiB | status |",
           "|" + "---|" * 5]
    for c in rows:
        if c.get("skipped"):
            out.append(f"| {c['arch']} | {c['cell']} | — | — | skip |")
            continue
        if "error" in c:
            out.append(f"| {c['arch']} | {c['cell']} | — | — | ERROR |")
            continue
        peak = c.get("memory", {}).get("peak_memory_in_bytes", 0) / 2**30
        out.append(f"| {c['arch']} | {c['cell']} | {c['compile_s']:.0f} | "
                   f"{peak:.1f} | OK |")
    return "\n".join(out)


def delta_table(rows_v1, rows_v2):
    """Per-cell v1→v2 step-lower-bound deltas (single-pod)."""
    idx = {(c["arch"], c["cell"]): c for c in rows_v1}
    out = ["| arch | cell | lower-bound v1→v2 (s) | speedup | "
           "roofline v1→v2 |", "|" + "---|" * 5]
    for c2 in rows_v2:
        key = (c2["arch"], c2["cell"])
        c1 = idx.get(key)
        if not c1 or c1.get("skipped") or "error" in c1 or "error" in c2:
            continue
        r1 = c1.get("roofline_kernel_adjusted") or c1["roofline"]
        r2 = c2.get("roofline_kernel_adjusted") or c2["roofline"]
        t1, t2 = (r1["step_time_lower_bound_s"],
                  r2["step_time_lower_bound_s"])
        if t1 <= 0 or t2 <= 0:
            continue
        out.append(
            f"| {key[0]} | {key[1]} | {t1:.3f} → {t2:.3f} | "
            f"{t1 / t2:.2f}× | {r1['roofline_fraction']:.2%} → "
            f"{r2['roofline_fraction']:.2%} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun_v2")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--kind", default="roofline",
                    choices=("roofline", "compile", "delta"))
    ap.add_argument("--baseline-dir", default="experiments/dryrun")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh)
    if args.kind == "roofline":
        print(roofline_table(rows))
    elif args.kind == "compile":
        print(compile_table(rows))
    else:
        print(delta_table(load(args.baseline_dir, args.mesh), rows))


if __name__ == "__main__":
    main()
