"""Render the §Roofline table from the dry-run JSON artifacts.

Reads experiments/dryrun/<arch>_<shape>_<mesh>.json (written by
`python -m repro.launch.dryrun --all --out experiments/dryrun`) and emits
the per-cell three-term roofline summary used in EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# prefer the optimized (v2) sweep when present; fall back to the baseline
DRYRUN_DIRS = [os.path.join(_ROOT, "experiments", "dryrun_v2"),
               os.path.join(_ROOT, "experiments", "dryrun")]


def load_cells(mesh: str = "single") -> list[dict]:
    for d in DRYRUN_DIRS:
        if not os.path.isdir(d):
            continue
        out = []
        for fn in sorted(os.listdir(d)):
            if not fn.endswith(f"_{mesh}.json"):
                continue
            with open(os.path.join(d, fn)) as f:
                out.append(json.load(f))
        if out:
            return out
    raise FileNotFoundError(f"no *_{mesh}.json under {DRYRUN_DIRS}")


def render_table(cells: list[dict]) -> str:
    hdr = (f"{'arch':28s} {'cell':12s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'bound':>6s} {'useful':>7s} {'roofline':>9s} "
           f"{'peakGiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        if c.get("skipped"):
            lines.append(f"{c['arch']:28s} {c['cell']:12s} "
                         f"{'— skipped: ' + c['reason'][:60]}")
            continue
        if "error" in c:
            lines.append(f"{c['arch']:28s} {c['cell']:12s} ERROR "
                         f"{c['error'][:70]}")
            continue
        r = c.get("roofline_kernel_adjusted",
                  c.get("roofline_extrapolated", c.get("roofline")))
        peak = c.get("memory", {}).get("peak_memory_in_bytes", 0) / 2**30
        lines.append(
            f"{c['arch']:28s} {c['cell']:12s} "
            f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
            f"{r['collective_s']:9.4f} "
            f"{r['bottleneck'].split('_')[0]:>6s} "
            f"{r['useful_flop_ratio']:7.2%} "
            f"{r['roofline_fraction']:9.2%} {peak:8.1f}")
    return "\n".join(lines)


def run(echo: bool = True, mesh: str = "single") -> dict:
    cells = load_cells(mesh)
    table = render_table(cells)
    if echo:
        print(table)
    ok = [c for c in cells if "error" not in c and not c.get("skipped")]
    out = {
        "n_cells": len(cells),
        "n_ok": len(ok),
        "n_skipped": sum(1 for c in cells if c.get("skipped")),
        "n_error": sum(1 for c in cells if "error" in c),
        "table": table,
    }
    emit(f"roofline_{mesh}", out, echo=False)
    return out


if __name__ == "__main__":
    run()
