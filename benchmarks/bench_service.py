"""Pool-service overhead: streaming submission throughput and
submit-to-first-match latency at 1k and 10k jobs.

The service layer puts a quiescent-injection step (`driver.call`) and a
serializable pending-op ledger between the client and the raw
`Simulation` — this bench guards that the streaming surface stays
cheap as traces grow:

  * submit_jobs_per_sec  — wall rate of a one-shot immediate
    `PoolClient.submit` for the whole trace
  * stream_jobs_per_sec  — `at_trace_times=True`: one ledger op
    scheduled per record
  * first_match_s        — simulated seconds from the first arrival to
    the first running job (matchmaking pipeline latency)
  * drain wall time / jobs-per-sec at each scale

Usage:
    python benchmarks/bench_service.py [--jobs 1000 10000]
        [--budget-s SECONDS]
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import Timer, emit
from repro.service import PoolClient, PoolService
from repro.workload.compare import FEDERATION_INI
from repro.workload.generators import diurnal_day

INI = FEDERATION_INI.format(routing="cheapest-first", onprem_nodes=4,
                            cloud_max_nodes=24, spot_max_nodes=24)


def mk_service() -> PoolService:
    return PoolService(INI, tick_s=30.0, negotiate_interval_s=60.0,
                       metrics_interval_s=300.0, seed=0, speed=None)


def one_scale(n_jobs: int, *, seed: int = 7) -> dict:
    trace = diurnal_day(n_jobs, seed=seed, duration_s=86400.0)
    recs = [r.to_obj() for r in trace.records]

    # immediate-mode throughput (everything enters the queue at t=now);
    # a throwaway service so the real run below starts clean
    probe = PoolClient(mk_service())
    with Timer() as t_imm:
        probe.submit(recs)

    svc = mk_service()
    client = PoolClient(svc)
    with Timer() as t_stream:
        r = client.submit(recs, at_trace_times=True, at=0.0)
    assert r["scheduled"] == n_jobs, (r, n_jobs)

    # submit -> first match, in simulated time (tick_s resolution)
    first_arrival = trace.records[0].arrival_s
    while svc.sim.pool_queue.n_running() == 0:
        svc.sim.run(svc.sim.now + 30.0)
    first_match_s = svc.sim.now - first_arrival

    with Timer() as t_drain:
        svc.run_until_drained()
    n_done = svc.completed_stats().n
    assert n_done == n_jobs, (n_done, n_jobs)
    return {
        "jobs": n_jobs,
        "submit_jobs_per_sec": round(n_jobs / max(t_imm.s, 1e-9), 1),
        "stream_jobs_per_sec": round(n_jobs / max(t_stream.s, 1e-9), 1),
        "first_match_s": round(first_match_s, 1),
        "drain_wall_s": round(t_drain.s, 3),
        "drain_jobs_per_sec": round(n_jobs / max(t_drain.s, 1e-9), 1),
        "final_t": svc.sim.now,
    }


def run(*, jobs=(1000, 10000), echo: bool = True) -> dict:
    with Timer() as total:
        cells = {f"jobs_{n}": one_scale(n) for n in jobs}
    payload = {**cells, "total_wall_s": round(total.s, 1)}
    emit("service", payload, echo=echo)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--jobs", type=int, nargs="+", default=[1000, 10000])
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail (exit 2) if the whole bench exceeds this "
                         "wall time")
    args = ap.parse_args(argv)
    try:
        payload = run(jobs=tuple(args.jobs))
    except AssertionError as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    if (args.budget_s is not None
            and payload["total_wall_s"] > args.budget_s):
        print(f"FAIL: wall {payload['total_wall_s']:.1f}s exceeds "
              f"budget {args.budget_s:.1f}s", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
