"""Benchmark driver: `PYTHONPATH=src python -m benchmarks.run`.

Runs every control-plane benchmark (one per paper figure/claim) plus the
kernel table.  The 40-cell dry-run/roofline sweep is separate
(`python -m repro.launch.dryrun --all`) because it needs the 512-device
XLA flag at process start; `benchmarks.bench_roofline` renders its output.
"""
from __future__ import annotations

import sys
import time


def main():
    from benchmarks import common
    from benchmarks import (
        bench_event_engine, bench_federation, bench_flocking,
        bench_grouping, bench_kernels, bench_matchmaking,
        bench_preemption, bench_scaledown, bench_service,
        bench_stragglers, bench_trace_replay, bench_tracking,
        bench_utilization,
    )

    t0 = time.time()
    failures = []
    for mod in (bench_tracking, bench_grouping, bench_preemption,
                bench_scaledown, bench_stragglers, bench_utilization,
                bench_federation, bench_event_engine, bench_trace_replay,
                bench_flocking, bench_matchmaking, bench_service,
                bench_kernels):
        name = mod.__name__.split(".")[-1]
        t = time.time()
        try:
            common.begin_bench()
            mod.run(echo=False)
            print(f"[bench] {name:20s} OK   ({time.time()-t:.1f}s)")
        except Exception as e:
            failures.append((name, e))
            print(f"[bench] {name:20s} FAIL {type(e).__name__}: {e}")

    # roofline rendering if dry-run artifacts exist
    try:
        from benchmarks import bench_roofline
        common.begin_bench()
        bench_roofline.run(echo=True)
        print("[bench] bench_roofline      OK")
    except FileNotFoundError:
        print("[bench] bench_roofline      SKIP (run repro.launch.dryrun "
              "--all first)")
    except Exception as e:
        failures.append(("bench_roofline", e))
        print(f"[bench] bench_roofline      FAIL {e}")

    print(f"[bench] total {time.time()-t0:.1f}s, {len(failures)} failures")
    print("[bench] JSON artifacts in experiments/bench/")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
