"""Backend federation: routing-policy cost/latency trade-off.

The paper's single-provider deployments (on-prem §2–§5, NAP cloud §6)
become backends behind one provisioner; the routing policy decides where
each group's deficit lands.  Same bursty workload on the same
three-provider federation (static on-prem, billed on-demand cloud,
cheaper reclaimable spot), one row per policy: dollars spent, job wait,
makespan, and the per-backend pod split.

Expectations encoded as assertions:
  * cheapest-first never spends more than fill-cloud-first
  * every policy drains the queue (reclaims included)
"""
from __future__ import annotations

from benchmarks.common import Timer, emit
from repro.core import Simulation, gpu_job, load_ini

INI = """\
[provision]
submit_interval_s=30
idle_timeout_s=180
startup_delay_s=30
routing_policy={policy}

[backend:onprem]
kind=static
nodes=2
capacity_dict=cpu:64,gpu:8,memory:512,disk:1024

[backend:cloud]
kind=autoscale
capacity_dict=cpu:64,gpu:7,memory:512,disk:1024
max_nodes=6
node_hourly_cost=2.5
provision_delay_s=90
scale_down_delay_s=300

[backend:spot]
kind=autoscale
spot=true
capacity_dict=cpu:64,gpu:8,memory:512,disk:1024
max_nodes=6
node_hourly_cost=0.8
provision_delay_s=90
scale_down_delay_s=300
weight=2.0
"""

POLICIES = ("fill-first", "cheapest-first", "weighted-spread",
            "spot-with-fallback")


def _run_policy(policy: str, seed: int = 0) -> dict:
    cfg = load_ini(INI.format(policy=policy))
    sim = Simulation.from_config(cfg, tick_s=5, seed=seed)
    sim.submit_jobs(0, [gpu_job(900, gpus=1) for _ in range(70)])
    sim.submit_jobs(1800, [gpu_job(600, gpus=1) for _ in range(30)])
    sim.inject_pod_preemption(500, frac=0.4, backend="spot")
    with Timer() as t:
        sim.run_until_drained(max_t=60000)
    assert sim.queue.drained(), f"{policy} failed to drain"
    s = sim.summary()
    return {
        "policy": policy,
        "cost_total": round(s["cost_total"], 2),
        "mean_wait_s": round(s["jobs"]["mean_wait_s"], 1),
        "p95_wait_s": round(s["jobs"]["p95_wait_s"], 1),
        "makespan_s": sim.now,
        "pods_per_backend": dict(
            sim.provisioner.stats.per_backend_submitted),
        "spot_reclaimed": s["backends"]["spot"]["pods_reclaimed"],
        "cloud_waste_fraction": round(
            s["backends"]["cloud"]["waste_fraction"], 3),
        "wall_s": round(t.s, 2),
    }


def run(echo: bool = True) -> dict:
    rows = [_run_policy(p) for p in POLICIES]
    out = {r["policy"]: r for r in rows}
    by = {r["policy"]: r for r in rows}
    # cheapest-first routes around billed capacity whenever it can
    assert (by["cheapest-first"]["cost_total"]
            <= by["fill-first"]["cost_total"] + 1e-9)
    # spot-with-fallback leans on the reclaimable pool hardest
    assert (by["spot-with-fallback"]["pods_per_backend"].get("spot", 0)
            >= max(r["pods_per_backend"].get("spot", 0)
                   for r in rows if r["policy"] != "spot-with-fallback"))
    emit("federation", out, echo=echo)
    return out


if __name__ == "__main__":
    run()
