"""Matchmaker backends head-to-head: one negotiation step at scale.

ISSUE 6 acceptance: the jitted JAX water-fill must be >= 5x faster than
the NumPy reference on the 100k-job tier, claim-for-claim identical.

What is timed is ONE `Matchmaker.match` call — the pure negotiation
step both backends expose behind the protocol — on the paper's
demand >> supply shape: a large idle backlog (cohort-compressed, the
job queue's cohort index does that for free) against a Kubernetes pool
of a few hundred partitionable slots (bench_event_engine provisions 600
pods for its 100k-job campaign).  Tiers scale the backlog:

    tier    jobs      cohorts  workers
    10k     10_000      512      128
    100k    100_000    4_096      512
    1m      1_000_000  16_384    1_024

The JAX timing EXCLUDES the one-off jit trace (warmup) and INCLUDES
host->device transfer of the cycle's arrays — it is the steady-state
per-cycle cost a simulation pays.  `identical` is a hard gate: a fast
wrong matchmaker fails the bench before any ratio is read.

The END-TO-END tier (ISSUE 8) times the whole Collector pipeline —
problem build from live cohorts, match, claim apply-back — over a
K-wave submission campaign, three series on identical pools:

    numpy      K × run_cycle against the NumPy reference
    jax        K × run_cycle against the jitted water-fill (per-cycle
               dispatch: K problem builds, K device round-trips)
    fused      K × stage_cycle + one flush through the fused K-cycle
               jit (ONE problem build, ONE device dispatch)

`e2e_identical` gates all three claim maps (jid, worker, timestamp)
bitwise; `--e2e-min-ratio` gates jax_s / fused_s at the first tier.

Usage:
    python benchmarks/bench_matchmaking.py [--tiers 10k,100k,1m]
        [--budget-s SECONDS] [--min-ratio 5] [--repeats 3]
        [--e2e-min-ratio 1.5]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import Timer, emit
from repro.core.matchmaker import (
    HAVE_JAX, MatchProblem, NumpyMatchmaker, make_matchmaker,
)

TIERS = {
    "10k": dict(jobs=10_000, C=512, W=128),
    "100k": dict(jobs=100_000, C=4_096, W=512),
    "1m": dict(jobs=1_000_000, C=16_384, W=1_024),
}
R = 6


def build_problem(jobs: int, C: int, W: int, seed: int = 0) -> MatchProblem:
    """The paper regime: heterogeneous 1-4 cpu / 0-1 gpu requests,
    cohort-compressed backlog, a pool that drains mid-cycle."""
    rng = np.random.default_rng(seed)
    requests = np.zeros((C, R))
    requests[:, 0] = rng.integers(1, 5, size=C)           # cpus
    requests[:, 1] = rng.integers(0, 2, size=C)           # gpus
    requests[:, 2] = rng.integers(1, 9, size=C)           # memory GB
    demand = np.full(C, jobs // C, dtype=np.int64)
    demand[: jobs % C] += 1
    free = np.zeros((W, R))
    free[:, 0] = rng.integers(8, 65, size=W)
    free[:, 1] = rng.integers(0, 9, size=W)
    free[:, 2] = rng.integers(32, 257, size=W)
    compat = rng.random((C, W)) < 0.9
    return MatchProblem(
        keys=[(0, c) for c in range(C)], requests=requests,
        demand=demand, order=rng.permutation(C).astype(np.int64),
        free=free, capacity=free.copy(),
        compat=np.asarray(compat, dtype=bool))


def best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# -- end-to-end tier: Collector build -> match -> apply over K waves ---------

E2E = {
    # waves of NEW cohort shapes (memory varies per wave) so early full
    # drains never re-arrive — the fused batch stays on the jit path
    "10k": dict(jobs=10_000, waves=16, W=128, cpus=64),
    "100k": dict(jobs=100_000, waves=16, W=512, cpus=64),
}


def _e2e_pool(matchmaker, spec, batch: int):
    """A fresh pool + pre-loaded K-wave queue (setup is NOT timed).
    Workers pre-boot at t=0 and absorb roughly a third of the campaign;
    wave k's jobs carry submit time t_k - 1, so the staged flush and the
    `max_submit` replay see identical per-cycle visibility."""
    from repro.core.classad import ClassAdExpr
    from repro.core.jobqueue import Job, JobQueue
    from repro.core.worker import Collector, Worker

    col = Collector(matchmaker=matchmaker, negotiation_batch=batch)
    for i in range(spec["W"]):
        w = Worker(name=f"w{i}", ad={"cpus": spec["cpus"], "memory": 8192},
                   start_expr=ClassAdExpr("True"))
        w.booted_at = 0.0
        col.advertise(w)
    q = JobQueue()
    waves = spec["waves"]
    per_wave = spec["jobs"] // waves
    times = [60.0 * (k + 1) for k in range(waves)]
    for k, t in enumerate(times):
        for i in range(per_wave):
            q.submit(Job(ad={"request_cpus": 1 + (i % 4),
                             "request_memory": 4 + 8 * k,   # new shapes/wave
                             "owner": f"u{i % 4}",
                             "runtime_s": 1e6}), now=t - 1.0)
    return col, q, times


def _claim_map(q):
    return sorted((j.jid, j.claimed_by, j.attempt_started_at)
                  for j in q.jobs() if j.claimed_by is not None)


def run_e2e(tier: str, repeats: int, jax_mm, numpy_mm) -> dict:
    spec = E2E[tier]
    row = dict(spec)

    def percycle(mm):
        col, q, times = _e2e_pool(mm, spec, batch=1)
        t0 = time.perf_counter()
        claimed = sum(col.run_cycle(q, t, max_submit=t) for t in times)
        return time.perf_counter() - t0, claimed, _claim_map(q)

    def fused(mm):
        col, q, times = _e2e_pool(mm, spec, batch=spec["waves"])
        t0 = time.perf_counter()
        claimed = sum(col.stage_cycle(q, t) for t in times)
        claimed += col.quiesce()
        return (time.perf_counter() - t0, claimed, _claim_map(q),
                col.fused_batches, col.staged_fallbacks)

    np_s, np_claimed, np_map = min(
        (percycle(numpy_mm) for _ in range(repeats)), key=lambda r: r[0])
    row["numpy_s"] = round(np_s, 4)
    row["claimed"] = np_claimed
    if jax_mm is None:
        row.update(jax_s=None, fused_s=None, fused_ratio=None,
                   e2e_identical=None, fused_batches=0)
        return row
    percycle(jax_mm)                                  # warmup: jit trace
    fused(jax_mm)
    jx_s, jx_claimed, jx_map = min(
        (percycle(jax_mm) for _ in range(repeats)), key=lambda r: r[0])
    fu_s, fu_claimed, fu_map, fb, ffb = min(
        (fused(jax_mm) for _ in range(repeats)), key=lambda r: r[0])
    row["jax_s"] = round(jx_s, 4)
    row["fused_s"] = round(fu_s, 4)
    row["fused_ratio"] = round(jx_s / fu_s, 2)
    row["fused_batches"] = fb
    row["staged_fallbacks"] = ffb
    row["e2e_identical"] = bool(np_map == jx_map == fu_map
                                and np_claimed == jx_claimed == fu_claimed)
    return row


def run(echo: bool = True, tiers=("10k", "100k"), repeats: int = 5,
        e2e_tiers=("10k",), e2e_repeats: int = 3):
    ref = NumpyMatchmaker()
    jaxmm = make_matchmaker("jax") if HAVE_JAX else None
    out = {"have_jax": HAVE_JAX, "tiers": {}, "e2e": {}}
    with Timer() as total:
        for tier in tiers:
            spec = TIERS[tier]
            p = build_problem(**spec)
            row = dict(spec)
            plan_ref = ref.match(p)
            row["claimed"] = plan_ref.claimed
            row["numpy_s"] = best_of(lambda: ref.match(p), repeats)
            if jaxmm is not None:
                plan_jax = jaxmm.match(p)          # warmup: jit trace
                row["identical"] = bool(
                    np.array_equal(plan_ref.takes, plan_jax.takes)
                    and np.allclose(plan_ref.free_after,
                                    plan_jax.free_after))
                row["jax_s"] = best_of(lambda: jaxmm.match(p), repeats)
                row["ratio"] = round(row["numpy_s"] / row["jax_s"], 2)
            else:
                row["identical"] = None
                row["jax_s"] = row["ratio"] = None
            out["tiers"][tier] = row
        for tier in e2e_tiers:
            out["e2e"][tier] = run_e2e(tier, e2e_repeats, jaxmm, ref)
    out["wall_s"] = round(total.s, 2)
    emit("matchmaking", out, echo=echo)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiers", default="10k,100k",
                    help="comma list from 10k,100k,1m")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if the whole bench exceeds this wall time")
    ap.add_argument("--min-ratio", type=float, default=None,
                    help="fail if the jax/numpy speedup at the largest "
                         "requested tier is below this")
    ap.add_argument("--e2e-tiers", default="10k",
                    help="comma list from 10k,100k (empty disables e2e)")
    ap.add_argument("--e2e-min-ratio", type=float, default=None,
                    help="fail if the fused-batch speedup over per-cycle "
                         "jax at the first e2e tier is below this")
    args = ap.parse_args(argv)
    tiers = [t.strip() for t in args.tiers.split(",") if t.strip()]
    e2e_tiers = [t.strip() for t in args.e2e_tiers.split(",") if t.strip()]
    unknown = ([t for t in tiers if t not in TIERS]
               + [t for t in e2e_tiers if t not in E2E])
    if unknown:
        print(f"[bench] unknown tiers {unknown}; known: {sorted(TIERS)} "
              f"(e2e: {sorted(E2E)})", file=sys.stderr)
        return 2
    out = run(echo=True, tiers=tiers, repeats=args.repeats,
              e2e_tiers=e2e_tiers)
    rc = 0
    for tier in tiers:
        row = out["tiers"][tier]
        if row["identical"] is False:
            print(f"[bench] FAIL: jax plan diverges from the reference "
                  f"at tier {tier}", file=sys.stderr)
            rc = 1
    for tier in e2e_tiers:
        row = out["e2e"][tier]
        if row["e2e_identical"] is False:
            print(f"[bench] FAIL: e2e claim maps diverge across series "
                  f"at tier {tier}", file=sys.stderr)
            rc = 1
    if args.e2e_min_ratio is not None and e2e_tiers:
        top = out["e2e"][e2e_tiers[0]]
        if top["fused_ratio"] is None:
            print("[bench] FAIL: --e2e-min-ratio given but jax unavailable",
                  file=sys.stderr)
            rc = 1
        elif top["fused_batches"] < 1:
            print("[bench] FAIL: fused path never engaged "
                  f"(fallbacks={top['staged_fallbacks']})", file=sys.stderr)
            rc = 1
        elif top["fused_ratio"] < args.e2e_min_ratio:
            print(f"[bench] FAIL: fused speedup {top['fused_ratio']}x < "
                  f"{args.e2e_min_ratio}x at e2e tier {e2e_tiers[0]}",
                  file=sys.stderr)
            rc = 1
    top = out["tiers"][tiers[-1]]
    if args.min_ratio is not None:
        if top["ratio"] is None:
            print("[bench] FAIL: --min-ratio given but jax unavailable",
                  file=sys.stderr)
            rc = 1
        elif top["ratio"] < args.min_ratio:
            print(f"[bench] FAIL: jax speedup {top['ratio']}x < "
                  f"{args.min_ratio}x at tier {tiers[-1]}",
                  file=sys.stderr)
            rc = 1
    if args.budget_s is not None and out["wall_s"] > args.budget_s:
        print(f"[bench] FAIL: wall {out['wall_s']}s > budget "
              f"{args.budget_s}s", file=sys.stderr)
        rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
