"""Matchmaker backends head-to-head: one negotiation step at scale.

ISSUE 6 acceptance: the jitted JAX water-fill must be >= 5x faster than
the NumPy reference on the 100k-job tier, claim-for-claim identical.

What is timed is ONE `Matchmaker.match` call — the pure negotiation
step both backends expose behind the protocol — on the paper's
demand >> supply shape: a large idle backlog (cohort-compressed, the
job queue's cohort index does that for free) against a Kubernetes pool
of a few hundred partitionable slots (bench_event_engine provisions 600
pods for its 100k-job campaign).  Tiers scale the backlog:

    tier    jobs      cohorts  workers
    10k     10_000      512      128
    100k    100_000    4_096      512
    1m      1_000_000  16_384    1_024

The JAX timing EXCLUDES the one-off jit trace (warmup) and INCLUDES
host->device transfer of the cycle's arrays — it is the steady-state
per-cycle cost a simulation pays.  `identical` is a hard gate: a fast
wrong matchmaker fails the bench before any ratio is read.

Usage:
    python benchmarks/bench_matchmaking.py [--tiers 10k,100k,1m]
        [--budget-s SECONDS] [--min-ratio 5] [--repeats 3]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import Timer, emit
from repro.core.matchmaker import (
    HAVE_JAX, MatchProblem, NumpyMatchmaker, make_matchmaker,
)

TIERS = {
    "10k": dict(jobs=10_000, C=512, W=128),
    "100k": dict(jobs=100_000, C=4_096, W=512),
    "1m": dict(jobs=1_000_000, C=16_384, W=1_024),
}
R = 6


def build_problem(jobs: int, C: int, W: int, seed: int = 0) -> MatchProblem:
    """The paper regime: heterogeneous 1-4 cpu / 0-1 gpu requests,
    cohort-compressed backlog, a pool that drains mid-cycle."""
    rng = np.random.default_rng(seed)
    requests = np.zeros((C, R))
    requests[:, 0] = rng.integers(1, 5, size=C)           # cpus
    requests[:, 1] = rng.integers(0, 2, size=C)           # gpus
    requests[:, 2] = rng.integers(1, 9, size=C)           # memory GB
    demand = np.full(C, jobs // C, dtype=np.int64)
    demand[: jobs % C] += 1
    free = np.zeros((W, R))
    free[:, 0] = rng.integers(8, 65, size=W)
    free[:, 1] = rng.integers(0, 9, size=W)
    free[:, 2] = rng.integers(32, 257, size=W)
    compat = rng.random((C, W)) < 0.9
    return MatchProblem(
        keys=[(0, c) for c in range(C)], requests=requests,
        demand=demand, order=rng.permutation(C).astype(np.int64),
        free=free, capacity=free.copy(),
        compat=np.asarray(compat, dtype=bool))


def best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(echo: bool = True, tiers=("10k", "100k"), repeats: int = 5):
    ref = NumpyMatchmaker()
    jaxmm = make_matchmaker("jax") if HAVE_JAX else None
    out = {"have_jax": HAVE_JAX, "tiers": {}}
    with Timer() as total:
        for tier in tiers:
            spec = TIERS[tier]
            p = build_problem(**spec)
            row = dict(spec)
            plan_ref = ref.match(p)
            row["claimed"] = plan_ref.claimed
            row["numpy_s"] = best_of(lambda: ref.match(p), repeats)
            if jaxmm is not None:
                plan_jax = jaxmm.match(p)          # warmup: jit trace
                row["identical"] = bool(
                    np.array_equal(plan_ref.takes, plan_jax.takes)
                    and np.allclose(plan_ref.free_after,
                                    plan_jax.free_after))
                row["jax_s"] = best_of(lambda: jaxmm.match(p), repeats)
                row["ratio"] = round(row["numpy_s"] / row["jax_s"], 2)
            else:
                row["identical"] = None
                row["jax_s"] = row["ratio"] = None
            out["tiers"][tier] = row
    out["wall_s"] = round(total.s, 2)
    emit("matchmaking", out, echo=echo)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiers", default="10k,100k",
                    help="comma list from 10k,100k,1m")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if the whole bench exceeds this wall time")
    ap.add_argument("--min-ratio", type=float, default=None,
                    help="fail if the jax/numpy speedup at the largest "
                         "requested tier is below this")
    args = ap.parse_args(argv)
    tiers = [t.strip() for t in args.tiers.split(",") if t.strip()]
    unknown = [t for t in tiers if t not in TIERS]
    if unknown:
        print(f"[bench] unknown tiers {unknown}; known: {sorted(TIERS)}",
              file=sys.stderr)
        return 2
    out = run(echo=True, tiers=tiers, repeats=args.repeats)
    rc = 0
    for tier in tiers:
        row = out["tiers"][tier]
        if row["identical"] is False:
            print(f"[bench] FAIL: jax plan diverges from the reference "
                  f"at tier {tier}", file=sys.stderr)
            rc = 1
    top = out["tiers"][tiers[-1]]
    if args.min_ratio is not None:
        if top["ratio"] is None:
            print("[bench] FAIL: --min-ratio given but jax unavailable",
                  file=sys.stderr)
            rc = 1
        elif top["ratio"] < args.min_ratio:
            print(f"[bench] FAIL: jax speedup {top['ratio']}x < "
                  f"{args.min_ratio}x at tier {tiers[-1]}",
                  file=sys.stderr)
            rc = 1
    if args.budget_s is not None and out["wall_s"] > args.budget_s:
        print(f"[bench] FAIL: wall {out['wall_s']}s > budget "
              f"{args.budget_s}s", file=sys.stderr)
        rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
