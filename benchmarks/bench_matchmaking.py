"""Matchmaker backends head-to-head: one negotiation step at scale.

ISSUE 6 acceptance: the jitted JAX water-fill must be >= 5x faster than
the NumPy reference on the 100k-job tier, claim-for-claim identical.

What is timed is ONE `Matchmaker.match` call — the pure negotiation
step both backends expose behind the protocol — on the paper's
demand >> supply shape: a large idle backlog (cohort-compressed, the
job queue's cohort index does that for free) against a Kubernetes pool
of a few hundred partitionable slots (bench_event_engine provisions 600
pods for its 100k-job campaign).  Tiers scale the backlog:

    tier    jobs      cohorts  workers
    10k     10_000      512      128
    100k    100_000    4_096      512
    1m      1_000_000  16_384    1_024

The JAX timing EXCLUDES the one-off jit trace (warmup) and INCLUDES
host->device transfer of the cycle's arrays — it is the steady-state
per-cycle cost a simulation pays.  `identical` is a hard gate: a fast
wrong matchmaker fails the bench before any ratio is read.

The END-TO-END tier (ISSUE 8) times the whole Collector pipeline —
problem build from live cohorts, match, claim apply-back — over a
K-wave submission campaign, three series on identical pools:

    numpy      K × run_cycle against the NumPy reference
    jax        K × run_cycle against the jitted water-fill (per-cycle
               dispatch: K problem builds, K device round-trips)
    fused      K × stage_cycle + one flush through the fused K-cycle
               jit (ONE problem build, ONE device dispatch)

`e2e_identical` gates all three claim maps (jid, worker, timestamp)
bitwise; `--e2e-min-ratio` gates jax_s / fused_s at the first tier.

The PREVIEW REPLAY tier (ISSUE 10) streams the 2k-job diurnal day
through the standard federation with the profiler on, once per backend,
and splits the provisioner's reconcile wall into preview vs the rest —
`--preview-max-ratio` gates the jax preview wall against numpy's (the
batched vmapped preview dispatch must not pay per-call jit overhead).

Usage:
    python benchmarks/bench_matchmaking.py [--tiers 10k,100k,1m]
        [--budget-s SECONDS] [--min-ratio 5] [--repeats 3]
        [--e2e-min-ratio 1.5] [--preview-jobs 2000]
        [--preview-max-ratio 2]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import Timer, emit
from repro.core.matchmaker import (
    HAVE_JAX, MatchProblem, NumpyMatchmaker, make_matchmaker,
)

TIERS = {
    "10k": dict(jobs=10_000, C=512, W=128),
    "100k": dict(jobs=100_000, C=4_096, W=512),
    "1m": dict(jobs=1_000_000, C=16_384, W=1_024),
}
R = 6


def build_problem(jobs: int, C: int, W: int, seed: int = 0) -> MatchProblem:
    """The paper regime: heterogeneous 1-4 cpu / 0-1 gpu requests,
    cohort-compressed backlog, a pool that drains mid-cycle."""
    rng = np.random.default_rng(seed)
    requests = np.zeros((C, R))
    requests[:, 0] = rng.integers(1, 5, size=C)           # cpus
    requests[:, 1] = rng.integers(0, 2, size=C)           # gpus
    requests[:, 2] = rng.integers(1, 9, size=C)           # memory GB
    demand = np.full(C, jobs // C, dtype=np.int64)
    demand[: jobs % C] += 1
    free = np.zeros((W, R))
    free[:, 0] = rng.integers(8, 65, size=W)
    free[:, 1] = rng.integers(0, 9, size=W)
    free[:, 2] = rng.integers(32, 257, size=W)
    compat = rng.random((C, W)) < 0.9
    return MatchProblem(
        keys=[(0, c) for c in range(C)], requests=requests,
        demand=demand, order=rng.permutation(C).astype(np.int64),
        free=free, capacity=free.copy(),
        compat=np.asarray(compat, dtype=bool))


def best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# -- replay tier: provisioner preview wall over the 2k diurnal day -----------

def run_preview_replay(n_jobs: int = 2_000, duration_s: float = 14_400.0,
                       seed: int = 3, batch: int = 8) -> dict:
    """ISSUE 10 acceptance surface: stream the diurnal trace through
    the standard federation with the profiler on, once per backend, and
    report where the provisioner's reconcile wall goes.  The jax
    backend's batched preview dispatch (device-resident constants, no
    per-call problem rebuild) must keep its preview wall within the
    same order as numpy's — the `--preview-max-ratio` CI guard."""
    from repro.workload.compare import standard_policy
    from repro.workload.generators import diurnal_day
    from repro.workload.replay import replay_trace

    out: dict = {"jobs": n_jobs, "duration_s": duration_s, "seed": seed,
                 "negotiation_batch": batch}
    backends = ("numpy",) + (("jax",) if HAVE_JAX else ())
    for mm in backends:
        trace = diurnal_day(n_jobs, seed=seed, duration_s=duration_s)
        # fusion-friendly cadence: negotiations fire every 20s INSIDE a
        # 60s tick/reconcile/metrics grid, so the [20,40] windows carry
        # no observer events and the backlog-driven deferral can stage
        # 2+ cycles per flush (the default 30s tick grid puts a
        # reconcile on every negotiation instant, vetoing every window)
        spec = standard_policy("fill-first", tick_s=60.0,
                               negotiate_interval_s=20.0,
                               metrics_interval_s=60.0)
        spec.ini = spec.ini.replace(
            "[provision]\n",
            f"[provision]\nmatchmaker={mm}\nnegotiation_batch={batch}\n", 1)
        sim = spec.build(telemetry=True)
        replay_trace(sim, trace, coalesce_s=0.0)
        t0 = time.perf_counter()
        sim.run_until_drained(max_t=5e6)
        wall = time.perf_counter() - t0
        assert sim.queue.drained(), f"{mm} replay failed to drain"
        totals = sim.collector.profiler.phase_totals()
        col = sim.collector
        fallbacks = {k[0]: int(c.value)
                     for k, c in col._c_fallbacks.children.items()}
        flushes = col.fused_batches + col.staged_fallbacks
        out[mm] = {
            "wall_s": round(wall, 3),
            "reconcile_s": round(totals["reconcile_s"], 3),
            "preview_s": round(totals["preview_s"], 3),
            "preview_legacy": col.preview_legacy,
            "jit_compiles_by_path": totals["jit_compiles_by_path"],
            "fused_batches": col.fused_batches,
            "fused_cycles": col.fused_cycles,
            "fallbacks": fallbacks,
            "single_cycle_fraction": (
                round(fallbacks.get("single_cycle", 0) / flushes, 3)
                if flushes else None),
        }
    if "jax" in out and out["numpy"]["preview_s"] > 0:
        out["preview_ratio"] = round(
            out["jax"]["preview_s"] / out["numpy"]["preview_s"], 3)
    return out


# -- end-to-end tier: Collector build -> match -> apply over K waves ---------

E2E = {
    # waves of NEW cohort shapes (memory varies per wave) so early full
    # drains never re-arrive — the fused batch stays on the jit path
    "10k": dict(jobs=10_000, waves=16, W=128, cpus=64),
    "100k": dict(jobs=100_000, waves=16, W=512, cpus=64),
}


def _e2e_pool(matchmaker, spec, batch: int):
    """A fresh pool + pre-loaded K-wave queue (setup is NOT timed).
    Workers pre-boot at t=0 and absorb roughly a third of the campaign;
    wave k's jobs carry submit time t_k - 1, so the staged flush and the
    `max_submit` replay see identical per-cycle visibility."""
    from repro.core.classad import ClassAdExpr
    from repro.core.jobqueue import Job, JobQueue
    from repro.core.worker import Collector, Worker

    col = Collector(matchmaker=matchmaker, negotiation_batch=batch)
    for i in range(spec["W"]):
        w = Worker(name=f"w{i}", ad={"cpus": spec["cpus"], "memory": 8192},
                   start_expr=ClassAdExpr("True"))
        w.booted_at = 0.0
        col.advertise(w)
    q = JobQueue()
    waves = spec["waves"]
    per_wave = spec["jobs"] // waves
    times = [60.0 * (k + 1) for k in range(waves)]
    for k, t in enumerate(times):
        for i in range(per_wave):
            q.submit(Job(ad={"request_cpus": 1 + (i % 4),
                             "request_memory": 4 + 8 * k,   # new shapes/wave
                             "owner": f"u{i % 4}",
                             "runtime_s": 1e6}), now=t - 1.0)
    return col, q, times


def _claim_map(q):
    return sorted((j.jid, j.claimed_by, j.attempt_started_at)
                  for j in q.jobs() if j.claimed_by is not None)


def run_e2e(tier: str, repeats: int, jax_mm, numpy_mm) -> dict:
    spec = E2E[tier]
    row = dict(spec)

    def percycle(mm):
        col, q, times = _e2e_pool(mm, spec, batch=1)
        t0 = time.perf_counter()
        claimed = sum(col.run_cycle(q, t, max_submit=t) for t in times)
        return time.perf_counter() - t0, claimed, _claim_map(q)

    def fused(mm):
        col, q, times = _e2e_pool(mm, spec, batch=spec["waves"])
        t0 = time.perf_counter()
        claimed = sum(col.stage_cycle(q, t) for t in times)
        claimed += col.quiesce()
        return (time.perf_counter() - t0, claimed, _claim_map(q),
                col.fused_batches, col.staged_fallbacks)

    np_s, np_claimed, np_map = min(
        (percycle(numpy_mm) for _ in range(repeats)), key=lambda r: r[0])
    row["numpy_s"] = round(np_s, 4)
    row["claimed"] = np_claimed
    if jax_mm is None:
        row.update(jax_s=None, fused_s=None, fused_ratio=None,
                   e2e_identical=None, fused_batches=0)
        return row
    percycle(jax_mm)                                  # warmup: jit trace
    fused(jax_mm)
    jx_s, jx_claimed, jx_map = min(
        (percycle(jax_mm) for _ in range(repeats)), key=lambda r: r[0])
    fu_s, fu_claimed, fu_map, fb, ffb = min(
        (fused(jax_mm) for _ in range(repeats)), key=lambda r: r[0])
    row["jax_s"] = round(jx_s, 4)
    row["fused_s"] = round(fu_s, 4)
    row["fused_ratio"] = round(jx_s / fu_s, 2)
    row["fused_batches"] = fb
    row["staged_fallbacks"] = ffb
    row["e2e_identical"] = bool(np_map == jx_map == fu_map
                                and np_claimed == jx_claimed == fu_claimed)
    return row


def run(echo: bool = True, tiers=("10k", "100k"), repeats: int = 5,
        e2e_tiers=("10k",), e2e_repeats: int = 3,
        preview_jobs: int | None = 2_000):
    ref = NumpyMatchmaker()
    jaxmm = make_matchmaker("jax") if HAVE_JAX else None
    out = {"have_jax": HAVE_JAX, "tiers": {}, "e2e": {}}
    with Timer() as total:
        for tier in tiers:
            spec = TIERS[tier]
            p = build_problem(**spec)
            row = dict(spec)
            plan_ref = ref.match(p)
            row["claimed"] = plan_ref.claimed
            row["numpy_s"] = best_of(lambda: ref.match(p), repeats)
            if jaxmm is not None:
                plan_jax = jaxmm.match(p)          # warmup: jit trace
                row["identical"] = bool(
                    np.array_equal(plan_ref.takes, plan_jax.takes)
                    and np.allclose(plan_ref.free_after,
                                    plan_jax.free_after))
                row["jax_s"] = best_of(lambda: jaxmm.match(p), repeats)
                row["ratio"] = round(row["numpy_s"] / row["jax_s"], 2)
            else:
                row["identical"] = None
                row["jax_s"] = row["ratio"] = None
            out["tiers"][tier] = row
        for tier in e2e_tiers:
            out["e2e"][tier] = run_e2e(tier, e2e_repeats, jaxmm, ref)
        if preview_jobs:
            out["preview_replay"] = run_preview_replay(preview_jobs)
    out["wall_s"] = round(total.s, 2)
    meta = None
    pr = out.get("preview_replay")
    if pr:
        meta = {"reconcile_preview_split": {
            mm: {"reconcile_s": pr[mm]["reconcile_s"],
                 "preview_s": pr[mm]["preview_s"]}
            for mm in ("numpy", "jax") if mm in pr}}
    emit("matchmaking", out, echo=echo, meta=meta)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiers", default="10k,100k",
                    help="comma list from 10k,100k,1m")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if the whole bench exceeds this wall time")
    ap.add_argument("--min-ratio", type=float, default=None,
                    help="fail if the jax/numpy speedup at the largest "
                         "requested tier is below this")
    ap.add_argument("--e2e-tiers", default="10k",
                    help="comma list from 10k,100k (empty disables e2e)")
    ap.add_argument("--e2e-min-ratio", type=float, default=None,
                    help="fail if the fused-batch speedup over per-cycle "
                         "jax at the first e2e tier is below this")
    ap.add_argument("--preview-jobs", type=int, default=2_000,
                    help="diurnal replay size for the preview tier "
                         "(0 disables it)")
    ap.add_argument("--preview-max-ratio", type=float, default=None,
                    help="fail if the jax preview wall exceeds this "
                         "multiple of the numpy preview wall on the "
                         "diurnal replay tier")
    args = ap.parse_args(argv)
    tiers = [t.strip() for t in args.tiers.split(",") if t.strip()]
    e2e_tiers = [t.strip() for t in args.e2e_tiers.split(",") if t.strip()]
    unknown = ([t for t in tiers if t not in TIERS]
               + [t for t in e2e_tiers if t not in E2E])
    if unknown:
        print(f"[bench] unknown tiers {unknown}; known: {sorted(TIERS)} "
              f"(e2e: {sorted(E2E)})", file=sys.stderr)
        return 2
    out = run(echo=True, tiers=tiers, repeats=args.repeats,
              e2e_tiers=e2e_tiers, preview_jobs=args.preview_jobs or None)
    rc = 0
    if args.preview_max_ratio is not None:
        pr = out.get("preview_replay") or {}
        ratio = pr.get("preview_ratio")
        if ratio is None:
            print("[bench] FAIL: --preview-max-ratio given but the "
                  "preview replay tier did not run with jax",
                  file=sys.stderr)
            rc = 1
        elif ratio > args.preview_max_ratio:
            print(f"[bench] FAIL: jax preview wall {pr['jax']['preview_s']}s"
                  f" is {ratio}x numpy's {pr['numpy']['preview_s']}s "
                  f"(max {args.preview_max_ratio}x)", file=sys.stderr)
            rc = 1
        # backlog-driven live fusion must engage on the replay: with
        # negotiation_batch > 1 the quiet windows between the 60s
        # reconcile instants must defer flushes, so single-cycle
        # fallbacks can no longer be 100% of flushes (the pre-deferral
        # live engine quiesced every cycle in place).  Completion-heavy
        # stretches still veto deferral cycle-by-cycle — exactness over
        # batching — so the guard is on the fraction, not on a count of
        # non-empty fused batches (tests/test_live_fusion.py pins those
        # on a saturated pool).
        for mm in ("numpy", "jax"):
            row = pr.get(mm)
            if (row is not None and pr.get("negotiation_batch", 1) > 1
                    and not (row["single_cycle_fraction"] is not None
                             and row["single_cycle_fraction"] < 1.0)):
                print(f"[bench] FAIL: live fusion never engaged on the "
                      f"{mm} preview replay (single-cycle fallbacks were "
                      f"100% of flushes)", file=sys.stderr)
                rc = 1
    for tier in tiers:
        row = out["tiers"][tier]
        if row["identical"] is False:
            print(f"[bench] FAIL: jax plan diverges from the reference "
                  f"at tier {tier}", file=sys.stderr)
            rc = 1
    for tier in e2e_tiers:
        row = out["e2e"][tier]
        if row["e2e_identical"] is False:
            print(f"[bench] FAIL: e2e claim maps diverge across series "
                  f"at tier {tier}", file=sys.stderr)
            rc = 1
    if args.e2e_min_ratio is not None and e2e_tiers:
        top = out["e2e"][e2e_tiers[0]]
        if top["fused_ratio"] is None:
            print("[bench] FAIL: --e2e-min-ratio given but jax unavailable",
                  file=sys.stderr)
            rc = 1
        elif top["fused_batches"] < 1:
            print("[bench] FAIL: fused path never engaged "
                  f"(fallbacks={top['staged_fallbacks']})", file=sys.stderr)
            rc = 1
        elif top["fused_ratio"] < args.e2e_min_ratio:
            print(f"[bench] FAIL: fused speedup {top['fused_ratio']}x < "
                  f"{args.e2e_min_ratio}x at e2e tier {e2e_tiers[0]}",
                  file=sys.stderr)
            rc = 1
    top = out["tiers"][tiers[-1]]
    if args.min_ratio is not None:
        if top["ratio"] is None:
            print("[bench] FAIL: --min-ratio given but jax unavailable",
                  file=sys.stderr)
            rc = 1
        elif top["ratio"] < args.min_ratio:
            print(f"[bench] FAIL: jax speedup {top['ratio']}x < "
                  f"{args.min_ratio}x at tier {tiers[-1]}",
                  file=sys.stderr)
            rc = 1
    if args.budget_s is not None and out["wall_s"] > args.budget_s:
        print(f"[bench] FAIL: wall {out['wall_s']}s > budget "
              f"{args.budget_s}s", file=sys.stderr)
        rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
