"""§2 motivation: requirement grouping (C4) vs a uniform-HPA baseline.

Kubernetes' HorizontalPodAutoscaler assumes uniform stateless replicas:
one pod template for everyone.  With heterogeneous jobs the template must
be sized for the LARGEST request, so small jobs occupy big pods and waste
the difference.  The paper's provisioner groups jobs by requirement
signature and requests exactly-fitting pods.

Workload: a mix of 1-GPU/2-GPU/4-GPU jobs.  Both policies run on the same
cluster; we report resource-seconds provisioned, busy fraction, and
makespan.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.core import (
    ProvisionerConfig, Simulation, gpu_job, onprem_nodes,
)
from repro.core.groups import GroupSignature
from repro.core.provisioner import Provisioner


class UniformHPAProvisioner(Provisioner):
    """Baseline: one pod shape (the max over all requests), count driven
    by total idle jobs — HPA with a queue-depth metric."""

    def reconcile(self, now):
        idle = [j for j in self.queue.idle_jobs()
                if self.filter.evaluate(j.ad)]
        if not idle:
            return super().reconcile(now) if False else self.stats
        big = GroupSignature(
            cpus=max(int(j.ad.get("request_cpus", 1)) for j in idle),
            gpus=max(int(j.ad.get("request_gpus", 0)) for j in idle),
            memory_gb=max(int(j.ad.get("request_memory", 4))
                          for j in idle),
            disk_gb=8,
        )
        label = self._pod_group_label(big)
        pending = self._group_pending(label)
        unclaimed = self.collector.unclaimed_capacity()
        deficit = len(idle) - pending - unclaimed
        n = max(0, min(deficit, self.cfg.max_total_pods
                       - self._total_live_pods()))
        for _ in range(n):
            self._submit_pod(big, label, now)
        self.stats.submitted += n
        return self.stats


def _run_policy(uniform: bool, seed: int = 0):
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=180,
                            startup_delay_s=30, max_pods_per_group=100,
                            max_total_pods=200)
    sim = Simulation(cfg, nodes=onprem_nodes(6, gpus=8), tick_s=5,
                     seed=seed)
    if uniform:
        sim.provisioner.__class__ = UniformHPAProvisioner
    jobs = ([gpu_job(900, gpus=1, cpus=1) for _ in range(24)]
            + [gpu_job(900, gpus=2, cpus=2) for _ in range(8)]
            + [gpu_job(900, gpus=4, cpus=4) for _ in range(4)])
    sim.submit_jobs(0, jobs)
    sim.run_until_drained(max_t=30000)
    s = sim.summary()
    # resource-seconds provisioned vs used
    prov = sum(w.alive_s * w.ad.get("gpus", 0) for w in sim.all_workers)
    used = sum(j.runtime_s * j.ad.get("request_gpus", 0)
               for j in sim.queue.completed_log)
    return {
        "makespan_s": sim.now,
        "gpu_seconds_provisioned": prov,
        "gpu_seconds_used": used,
        "gpu_efficiency": used / prov if prov else 0.0,
        "mean_wait_s": s["jobs"]["mean_wait_s"],
        "pods": s["pods_submitted"],
    }


def run(echo: bool = True) -> dict:
    grouped = _run_policy(uniform=False)
    uniform = _run_policy(uniform=True)
    out = {"grouped (paper C4)": grouped, "uniform-HPA baseline": uniform,
           "efficiency_gain": grouped["gpu_efficiency"]
           / max(uniform["gpu_efficiency"], 1e-9)}
    emit("grouping", out, echo=echo)
    assert grouped["gpu_efficiency"] > uniform["gpu_efficiency"], (
        "grouping should beat uniform HPA on heterogeneous load")
    return out


if __name__ == "__main__":
    run()
