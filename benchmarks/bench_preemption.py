"""§5: preemptible operation — goodput vs preemption rate, with and
without job self-checkpointing (our JAX training jobs checkpoint; generic
OSG payloads restart from scratch).

The paper's claims: preemption is handled transparently (jobs reschedule
and finish) and enabling it increases science output because otherwise-
idle resources get used.  We sweep the spot-reclaim rate and report
completion + goodput; the "preemption off" row models NOT using the idle
resources at all (the admin's alternative).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import ProvisionerConfig, Simulation, gpu_job, onprem_nodes


def _run(reclaim_every_s: float | None, ckpt: float | None,
         seed: int = 0, n_jobs: int = 32):
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=180,
                            startup_delay_s=30)
    sim = Simulation(cfg, nodes=onprem_nodes(4, gpus=8), tick_s=5,
                     seed=seed)
    sim.submit_jobs(0, [gpu_job(1200, gpus=1, checkpoint_interval_s=ckpt)
                        for _ in range(n_jobs)])
    if reclaim_every_s:
        t = reclaim_every_s
        while t < 20000:
            sim.inject_pod_preemption(t, frac=0.3)
            t += reclaim_every_s
    sim.run_until_drained(max_t=40000)
    s = sim.summary()
    return {
        "completed": s["jobs"]["n"],
        "makespan_s": sim.now,
        "preemptions": s["jobs"].get("preemptions", 0),
        "goodput": s["jobs"].get("goodput", 1.0),
        "wasted_h": s["jobs"].get("wasted_s", 0) / 3600,
    }


def run(echo: bool = True) -> dict:
    out = {
        "no_preemption": _run(None, None),
        "reclaim_20min_restart": _run(1200, None),
        "reclaim_20min_ckpt5min": _run(1200, 300),
        "reclaim_10min_restart": _run(600, None),
        "reclaim_10min_ckpt5min": _run(600, 300),
    }
    for k, v in out.items():
        assert v["completed"] == 32, (k, v)  # transparency: all finish
    assert (out["reclaim_20min_ckpt5min"]["goodput"]
            >= out["reclaim_20min_restart"]["goodput"])
    emit("preemption", out, echo=echo)
    return out


if __name__ == "__main__":
    run()
